//! Typed experiment configuration with the paper's §IV values as defaults.

use super::Ini;
use anyhow::Result;

/// Generator-matrix entry distribution (§III-A: "standard normal
/// distribution (or, iid Bernoulli(½) distribution)").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeneratorKind {
    Gaussian,
    /// Rademacher ±1 — the zero-mean unit-variance form of Bernoulli(½).
    Bernoulli,
}

impl std::str::FromStr for GeneratorKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" | "normal" => Ok(Self::Gaussian),
            "bernoulli" | "rademacher" => Ok(Self::Bernoulli),
            other => anyhow::bail!("unknown generator kind '{other}'"),
        }
    }
}

/// How the one-time parity-upload *time* is accounted (§III-A setup).
///
/// The paper specifies the per-epoch packet-delay model precisely (Eqs.
/// 5–6) but not the setup-transfer time model; its figures (small initial
/// offsets in Fig. 2, coding gains > 1 in Figs. 4–5) are only consistent
/// with setup transfers that do NOT pay the per-packet latency of the
/// slowest adapted link. See DESIGN.md §Substitutions for the calibration
/// evidence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SetupCostKind {
    /// Bulk transfer at the *base* (best) link rate, with 1/(1−p)
    /// retransmission overhead. Matches the paper's observed figure
    /// magnitudes; the default.
    BaseRate,
    /// Bulk transfer at each device's *adapted* rate (ladder value).
    AdaptedRate,
    /// One geometric retransmission draw per parity row at the adapted
    /// rate — the most pessimistic reading (latency-style accounting).
    PerPacket,
}

impl std::str::FromStr for SetupCostKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "base-rate" | "base" => Ok(Self::BaseRate),
            "adapted-rate" | "adapted" => Ok(Self::AdaptedRate),
            "per-packet" => Ok(Self::PerPacket),
            other => anyhow::bail!("unknown setup cost model '{other}'"),
        }
    }
}

/// How the global dataset is split across devices.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ShardingKind {
    /// Equal shards (paper §IV: ℓᵢ = 300 for all i).
    Equal,
    /// Power-law shard sizes (devices "generate highly disparate amounts
    /// of training data", §I) with the given exponent.
    PowerLaw(f64),
    /// Dirichlet(α) label-free non-iid feature skew (future-work knob).
    Dirichlet(f64),
}

impl std::str::FromStr for ShardingKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("equal") {
            return Ok(Self::Equal);
        }
        if let Some(rest) = s.strip_prefix("powerlaw:") {
            return Ok(Self::PowerLaw(rest.parse()?));
        }
        if let Some(rest) = s.strip_prefix("dirichlet:") {
            return Ok(Self::Dirichlet(rest.parse()?));
        }
        anyhow::bail!("unknown sharding '{s}' (equal | powerlaw:<a> | dirichlet:<a>)")
    }
}

/// Every knob of the paper's evaluation (§IV), with the published values
/// as defaults. One struct drives data generation, the delay models, the
/// load optimizer and the training loop, so a config file (or CLI flags)
/// can reproduce any figure.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    // -- topology / data ---------------------------------------------------
    /// Number of edge devices (paper: 24).
    pub n_devices: usize,
    /// Training points per device (paper: ℓᵢ = 300).
    pub points_per_device: usize,
    /// Model dimension d (paper: 500).
    pub model_dim: usize,
    /// Signal-to-noise ratio of y = Xβ + z in dB (paper: 0 dB).
    pub snr_db: f64,
    /// Sharding policy.
    pub sharding: ShardingKind,

    // -- training ----------------------------------------------------------
    /// Learning rate μ (paper: 0.0085; applied as μ/m per Eq. 3).
    pub learning_rate: f64,
    /// Maximum training epochs.
    pub max_epochs: usize,
    /// Target NMSE stopping criterion (Fig. 4 uses 3e-4).
    pub target_nmse: f64,

    // -- heterogeneity (§IV ladders) ----------------------------------------
    /// Compute heterogeneity ν_comp ∈ [0, 1).
    pub nu_comp: f64,
    /// Link heterogeneity ν_link ∈ [0, 1).
    pub nu_link: f64,
    /// Base MAC rate of the fastest device, KMAC/s (paper: 1536).
    pub base_mac_rate_kmacs: f64,
    /// Master speed-up over the fastest device (paper: 10×).
    pub master_speedup: f64,
    /// Base link throughput, kbit/s (paper: 216).
    pub base_throughput_kbps: f64,
    /// Link erasure probability p (paper: 0.1).
    pub erasure_prob: f64,
    /// Header overhead fraction on packets (paper: 10%).
    pub header_overhead: f64,
    /// Memory-access overhead factor: μᵢ = mem_overhead_factor / aᵢ
    /// (paper: "50% memory access overhead" → 2/aᵢ).
    pub mem_overhead_factor: f64,

    // -- coding ------------------------------------------------------------
    /// Generator matrix distribution.
    pub generator: GeneratorKind,
    /// Redundancy δ = c / Σℓᵢ. `None` → use the optimizer's c = ℓ*_{n+1}(t*).
    pub delta: Option<f64>,
    /// Cap on parity rows the server accepts (c^up of Eq. 15);
    /// expressed as a fraction of m. (paper caps δ at 0.28).
    pub c_up_fraction: f64,
    /// Setup-transfer time accounting (see [`SetupCostKind`]).
    pub setup_cost: SetupCostKind,
    /// Fraction of devices sampled to participate each epoch (client
    /// selection — the paper's §V future-work extension). 1.0 = everyone
    /// (the paper's evaluation). The master's parity gradient compensates
    /// for the unsampled devices exactly like for stragglers.
    pub client_fraction: f64,
    /// Tolerance ε of the t* search (Eq. 16), in expected returned points.
    pub epsilon: f64,

    // -- plumbing ------------------------------------------------------------
    /// Root seed for all randomness.
    pub seed: u64,
    /// Artifact directory for the PJRT runtime (None → native fallback).
    pub artifacts_dir: Option<String>,
}

impl ExperimentConfig {
    /// The paper's §IV setup, verbatim.
    pub fn paper() -> Self {
        Self {
            n_devices: 24,
            points_per_device: 300,
            model_dim: 500,
            snr_db: 0.0,
            sharding: ShardingKind::Equal,
            learning_rate: 0.0085,
            max_epochs: 20_000,
            target_nmse: 3e-4,
            nu_comp: 0.2,
            nu_link: 0.2,
            base_mac_rate_kmacs: 1536.0,
            master_speedup: 10.0,
            base_throughput_kbps: 216.0,
            erasure_prob: 0.1,
            header_overhead: 0.10,
            mem_overhead_factor: 2.0,
            generator: GeneratorKind::Gaussian,
            delta: None,
            c_up_fraction: 0.28, // the largest δ the paper evaluates

            setup_cost: SetupCostKind::BaseRate,
            client_fraction: 1.0,
            epsilon: 1.0,
            seed: 0xCF1_2019,
            artifacts_dir: None,
        }
    }

    /// A scaled-down setup for tests/quickstart (seconds, not minutes).
    /// SNR is raised to 10 dB so the LS floor (≈ 2·10⁻⁴ at m=480, d=40)
    /// sits beneath the 10⁻³ stopping target, mirroring the paper-scale
    /// relationship between floor and targets.
    pub fn small() -> Self {
        Self {
            n_devices: 8,
            points_per_device: 60,
            model_dim: 40,
            snr_db: 10.0,
            max_epochs: 4_000,
            target_nmse: 1e-3,
            ..Self::paper()
        }
    }

    /// Total raw training points m = Σ ℓᵢ.
    pub fn total_points(&self) -> usize {
        self.n_devices * self.points_per_device
    }

    /// Merge values from an INI document (section `[experiment]`; any
    /// missing key keeps its current value).
    pub fn apply_ini(&mut self, ini: &Ini) -> Result<()> {
        const S: &str = "experiment";
        self.n_devices = ini.get_or(S, "n_devices", self.n_devices)?;
        self.points_per_device = ini.get_or(S, "points_per_device", self.points_per_device)?;
        self.model_dim = ini.get_or(S, "model_dim", self.model_dim)?;
        self.snr_db = ini.get_or(S, "snr_db", self.snr_db)?;
        if let Some(s) = ini.get(S, "sharding") {
            self.sharding = s.parse()?;
        }
        self.learning_rate = ini.get_or(S, "learning_rate", self.learning_rate)?;
        self.max_epochs = ini.get_or(S, "max_epochs", self.max_epochs)?;
        self.target_nmse = ini.get_or(S, "target_nmse", self.target_nmse)?;
        self.nu_comp = ini.get_or(S, "nu_comp", self.nu_comp)?;
        self.nu_link = ini.get_or(S, "nu_link", self.nu_link)?;
        self.base_mac_rate_kmacs = ini.get_or(S, "base_mac_rate_kmacs", self.base_mac_rate_kmacs)?;
        self.master_speedup = ini.get_or(S, "master_speedup", self.master_speedup)?;
        self.base_throughput_kbps =
            ini.get_or(S, "base_throughput_kbps", self.base_throughput_kbps)?;
        self.erasure_prob = ini.get_or(S, "erasure_prob", self.erasure_prob)?;
        self.header_overhead = ini.get_or(S, "header_overhead", self.header_overhead)?;
        self.mem_overhead_factor =
            ini.get_or(S, "mem_overhead_factor", self.mem_overhead_factor)?;
        if let Some(s) = ini.get(S, "generator") {
            self.generator = s.parse()?;
        }
        if let Some(s) = ini.get(S, "delta") {
            self.delta = if s.eq_ignore_ascii_case("auto") { None } else { Some(s.parse()?) };
        }
        if let Some(s) = ini.get(S, "setup_cost") {
            self.setup_cost = s.parse()?;
        }
        self.client_fraction = ini.get_or(S, "client_fraction", self.client_fraction)?;
        self.c_up_fraction = ini.get_or(S, "c_up_fraction", self.c_up_fraction)?;
        self.epsilon = ini.get_or(S, "epsilon", self.epsilon)?;
        self.seed = ini.get_or(S, "seed", self.seed)?;
        if let Some(s) = ini.get(S, "artifacts_dir") {
            self.artifacts_dir = if s.is_empty() { None } else { Some(s.to_string()) };
        }
        self.validate()
    }

    /// Sanity-check parameter ranges.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n_devices > 0, "n_devices must be > 0");
        anyhow::ensure!(self.model_dim > 0, "model_dim must be > 0");
        anyhow::ensure!(self.points_per_device > 0, "points_per_device must be > 0");
        anyhow::ensure!((0.0..1.0).contains(&self.nu_comp), "nu_comp in [0,1)");
        anyhow::ensure!((0.0..1.0).contains(&self.nu_link), "nu_link in [0,1)");
        anyhow::ensure!((0.0..1.0).contains(&self.erasure_prob), "erasure_prob in [0,1)");
        anyhow::ensure!(self.learning_rate > 0.0, "learning_rate must be > 0");
        anyhow::ensure!(self.base_mac_rate_kmacs > 0.0, "base_mac_rate_kmacs must be > 0");
        anyhow::ensure!(self.base_throughput_kbps > 0.0, "base_throughput_kbps must be > 0");
        if let Some(d) = self.delta {
            anyhow::ensure!((0.0..=1.0).contains(&d), "delta in [0,1]");
        }
        anyhow::ensure!(
            self.client_fraction > 0.0 && self.client_fraction <= 1.0,
            "client_fraction in (0,1]"
        );
        Ok(())
    }
}
