//! Typed experiment configuration with the paper's §IV values as defaults.

use super::Ini;
use anyhow::Result;

/// Generator-matrix entry distribution (§III-A: "standard normal
/// distribution (or, iid Bernoulli(½) distribution)").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeneratorKind {
    Gaussian,
    /// Rademacher ±1 — the zero-mean unit-variance form of Bernoulli(½).
    Bernoulli,
}

impl std::str::FromStr for GeneratorKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" | "normal" => Ok(Self::Gaussian),
            "bernoulli" | "rademacher" => Ok(Self::Bernoulli),
            other => anyhow::bail!("unknown generator kind '{other}'"),
        }
    }
}

/// How the one-time parity-upload *time* is accounted (§III-A setup).
///
/// The paper specifies the per-epoch packet-delay model precisely (Eqs.
/// 5–6) but not the setup-transfer time model; its figures (small initial
/// offsets in Fig. 2, coding gains > 1 in Figs. 4–5) are only consistent
/// with setup transfers that do NOT pay the per-packet latency of the
/// slowest adapted link. See DESIGN.md §Substitutions for the calibration
/// evidence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SetupCostKind {
    /// Bulk transfer at the *base* (best) link rate, with 1/(1−p)
    /// retransmission overhead. Matches the paper's observed figure
    /// magnitudes; the default.
    BaseRate,
    /// Bulk transfer at each device's *adapted* rate (ladder value).
    AdaptedRate,
    /// One geometric retransmission draw per parity row at the adapted
    /// rate — the most pessimistic reading (latency-style accounting).
    PerPacket,
}

impl std::str::FromStr for SetupCostKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "base-rate" | "base" => Ok(Self::BaseRate),
            "adapted-rate" | "adapted" => Ok(Self::AdaptedRate),
            "per-packet" => Ok(Self::PerPacket),
            other => anyhow::bail!("unknown setup cost model '{other}'"),
        }
    }
}

/// How the global dataset is split across devices.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ShardingKind {
    /// Equal shards (paper §IV: ℓᵢ = 300 for all i).
    Equal,
    /// Power-law shard sizes (devices "generate highly disparate amounts
    /// of training data", §I) with the given exponent.
    PowerLaw(f64),
    /// Dirichlet(α) label-free non-iid feature skew (future-work knob).
    Dirichlet(f64),
}

impl std::str::FromStr for ShardingKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("equal") {
            return Ok(Self::Equal);
        }
        if let Some(rest) = s.strip_prefix("powerlaw:") {
            return Ok(Self::PowerLaw(rest.parse()?));
        }
        if let Some(rest) = s.strip_prefix("dirichlet:") {
            return Ok(Self::Dirichlet(rest.parse()?));
        }
        anyhow::bail!("unknown sharding '{s}' (equal | powerlaw:<a> | dirichlet:<a>)")
    }
}

/// Per-epoch client participation: which devices are even *candidates*
/// for an epoch's gather. Composes with the paper's §V return-time
/// selection — sampling picks the candidate pool, the Eq. 16 deadline
/// then keeps the fastest returners within it, and the master's parity
/// gradient compensates for everyone else (unsampled and stragglers
/// alike, Eq. 18–19).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Participation {
    /// Every device participates every epoch (the paper's evaluation).
    All,
    /// A seeded uniform sample of `⌈f·n⌉` devices per epoch.
    Fraction(f64),
    /// A seeded uniform sample of exactly `k` devices per epoch
    /// (clamped to the fleet size) — the production-FL fixed-quorum
    /// shape, and the knob the million-device scale scenarios use.
    Count(usize),
}

impl std::str::FromStr for Participation {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("all") {
            return Ok(Self::All);
        }
        if let Some(rest) = s.strip_prefix("frac:") {
            return Ok(Self::Fraction(rest.parse()?));
        }
        if let Some(rest) = s.strip_prefix("count:") {
            return Ok(Self::Count(rest.parse()?));
        }
        anyhow::bail!("unknown participation '{s}' (all | frac:<f> | count:<k>)")
    }
}

/// How the per-device training data is held in memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataMode {
    /// The global dataset and every shard are materialized up front —
    /// exact, byte-stable, and O(m·d) resident (the default).
    Materialized,
    /// Devices hold shard *descriptors* (seed + row range); shard views
    /// are regenerated on demand from the descriptor stream, so resident
    /// memory is O(fleet metadata), not O(m·d). Statistically identical
    /// to materialized data but a different RNG layout, so results are
    /// not bit-comparable across modes. See docs/SCALING.md.
    Lean,
}

impl std::str::FromStr for DataMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "materialized" | "dense" => Ok(Self::Materialized),
            "lean" | "streamed" => Ok(Self::Lean),
            other => anyhow::bail!("unknown data mode '{other}' (materialized | lean)"),
        }
    }
}

/// Every knob of the paper's evaluation (§IV), with the published values
/// as defaults. One struct drives data generation, the delay models, the
/// load optimizer and the training loop, so a config file (or CLI flags)
/// can reproduce any figure.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    // -- topology / data ---------------------------------------------------
    /// Number of edge devices (paper: 24).
    pub n_devices: usize,
    /// Training points per device (paper: ℓᵢ = 300).
    pub points_per_device: usize,
    /// Model dimension d (paper: 500).
    pub model_dim: usize,
    /// Signal-to-noise ratio of y = Xβ + z in dB (paper: 0 dB).
    pub snr_db: f64,
    /// Sharding policy.
    pub sharding: ShardingKind,

    // -- training ----------------------------------------------------------
    /// Learning rate μ (paper: 0.0085; applied as μ/m per Eq. 3).
    pub learning_rate: f64,
    /// Maximum training epochs.
    pub max_epochs: usize,
    /// Target NMSE stopping criterion (Fig. 4 uses 3e-4).
    pub target_nmse: f64,

    // -- heterogeneity (§IV ladders) ----------------------------------------
    /// Compute heterogeneity ν_comp ∈ [0, 1).
    pub nu_comp: f64,
    /// Link heterogeneity ν_link ∈ [0, 1).
    pub nu_link: f64,
    /// Base MAC rate of the fastest device, KMAC/s (paper: 1536).
    pub base_mac_rate_kmacs: f64,
    /// Master speed-up over the fastest device (paper: 10×).
    pub master_speedup: f64,
    /// Base link throughput, kbit/s (paper: 216).
    pub base_throughput_kbps: f64,
    /// Link erasure probability p (paper: 0.1).
    pub erasure_prob: f64,
    /// Header overhead fraction on packets (paper: 10%).
    pub header_overhead: f64,
    /// Memory-access overhead factor: μᵢ = mem_overhead_factor / aᵢ
    /// (paper: "50% memory access overhead" → 2/aᵢ).
    pub mem_overhead_factor: f64,

    // -- coding ------------------------------------------------------------
    /// Generator matrix distribution.
    pub generator: GeneratorKind,
    /// Redundancy δ = c / Σℓᵢ. `None` → use the optimizer's c = ℓ*_{n+1}(t*).
    pub delta: Option<f64>,
    /// Cap on parity rows the server accepts (c^up of Eq. 15);
    /// expressed as a fraction of m. (paper caps δ at 0.28).
    pub c_up_fraction: f64,
    /// Setup-transfer time accounting (see [`SetupCostKind`]).
    pub setup_cost: SetupCostKind,
    /// Fraction of devices sampled to participate each epoch (client
    /// selection — the paper's §V future-work extension). 1.0 = everyone
    /// (the paper's evaluation). The master's parity gradient compensates
    /// for the unsampled devices exactly like for stragglers.
    pub client_fraction: f64,
    /// Tolerance ε of the t* search (Eq. 16), in expected returned points.
    pub epsilon: f64,

    // -- scale (million-device sim backend) ----------------------------------
    /// Per-epoch participation sampling (see [`Participation`]).
    /// `All` (default) reproduces the pre-sampling behavior exactly.
    pub participation: Participation,
    /// Data residency (see [`DataMode`]). `Materialized` (default) is the
    /// exact paper path; `Lean` streams shard views for huge fleets.
    pub data_mode: DataMode,
    /// Cap on retained convergence-trace points in a sim run's
    /// [`RunResult`](crate::coordinator::RunResult) (stride-doubling
    /// decimation keeps the first/last points and the curve's shape).
    /// 0 (default) retains every epoch — the pre-cap behavior.
    pub trace_points: usize,
    /// Fan-in of the hierarchical aggregation tree in the sim gather.
    /// 0 (default) is the flat left-to-right sum (byte-identical to the
    /// pre-tree behavior); ≥ 2 reduces gradients in groups of this size.
    pub agg_fanin: usize,
    /// Number of distinct rungs on the §IV heterogeneity ladders
    /// (device i gets exponent `i mod tiers`). 0 (default) gives every
    /// device its own rung — the paper's ladder — which underflows to
    /// zero rates for huge fleets; the scale scenarios pin 24 tiers to
    /// mirror the paper's 24-device spread at any fleet size.
    pub ladder_tiers: usize,

    // -- plumbing ------------------------------------------------------------
    /// Root seed for all randomness.
    pub seed: u64,
    /// Artifact directory for the PJRT runtime (None → native fallback).
    pub artifacts_dir: Option<String>,
}

impl ExperimentConfig {
    /// The paper's §IV setup, verbatim.
    pub fn paper() -> Self {
        Self {
            n_devices: 24,
            points_per_device: 300,
            model_dim: 500,
            snr_db: 0.0,
            sharding: ShardingKind::Equal,
            learning_rate: 0.0085,
            max_epochs: 20_000,
            target_nmse: 3e-4,
            nu_comp: 0.2,
            nu_link: 0.2,
            base_mac_rate_kmacs: 1536.0,
            master_speedup: 10.0,
            base_throughput_kbps: 216.0,
            erasure_prob: 0.1,
            header_overhead: 0.10,
            mem_overhead_factor: 2.0,
            generator: GeneratorKind::Gaussian,
            delta: None,
            c_up_fraction: 0.28, // the largest δ the paper evaluates

            setup_cost: SetupCostKind::BaseRate,
            client_fraction: 1.0,
            epsilon: 1.0,
            participation: Participation::All,
            data_mode: DataMode::Materialized,
            trace_points: 0,
            agg_fanin: 0,
            ladder_tiers: 0,
            seed: 0xCF1_2019,
            artifacts_dir: None,
        }
    }

    /// A scaled-down setup for tests/quickstart (seconds, not minutes).
    /// SNR is raised to 10 dB so the LS floor (≈ 2·10⁻⁴ at m=480, d=40)
    /// sits beneath the 10⁻³ stopping target, mirroring the paper-scale
    /// relationship between floor and targets.
    pub fn small() -> Self {
        Self {
            n_devices: 8,
            points_per_device: 60,
            model_dim: 40,
            snr_db: 10.0,
            max_epochs: 4_000,
            target_nmse: 1e-3,
            ..Self::paper()
        }
    }

    /// Total raw training points m = Σ ℓᵢ.
    pub fn total_points(&self) -> usize {
        self.n_devices * self.points_per_device
    }

    /// Devices sampled as candidates each epoch, resolving
    /// [`Participation`] against the fleet size (and the legacy
    /// `client_fraction` spelling when participation is `All`). Returns
    /// `n_devices` when sampling is off — coordinators use `k == n` as
    /// the no-sampling fast path, so `count:<n>` and `frac:1` are
    /// byte-identical to `all`.
    pub fn sampled_per_epoch(&self) -> usize {
        let n = self.n_devices;
        match self.participation {
            Participation::All => {
                ((self.client_fraction * n as f64).round() as usize).clamp(1, n)
            }
            Participation::Fraction(f) => ((f * n as f64).round() as usize).clamp(1, n),
            Participation::Count(k) => k.clamp(1, n),
        }
    }

    /// Merge values from an INI document (section `[experiment]`; any
    /// missing key keeps its current value).
    pub fn apply_ini(&mut self, ini: &Ini) -> Result<()> {
        const S: &str = "experiment";
        self.n_devices = ini.get_or(S, "n_devices", self.n_devices)?;
        self.points_per_device = ini.get_or(S, "points_per_device", self.points_per_device)?;
        self.model_dim = ini.get_or(S, "model_dim", self.model_dim)?;
        self.snr_db = ini.get_or(S, "snr_db", self.snr_db)?;
        if let Some(s) = ini.get(S, "sharding") {
            self.sharding = s.parse()?;
        }
        self.learning_rate = ini.get_or(S, "learning_rate", self.learning_rate)?;
        self.max_epochs = ini.get_or(S, "max_epochs", self.max_epochs)?;
        self.target_nmse = ini.get_or(S, "target_nmse", self.target_nmse)?;
        self.nu_comp = ini.get_or(S, "nu_comp", self.nu_comp)?;
        self.nu_link = ini.get_or(S, "nu_link", self.nu_link)?;
        self.base_mac_rate_kmacs = ini.get_or(S, "base_mac_rate_kmacs", self.base_mac_rate_kmacs)?;
        self.master_speedup = ini.get_or(S, "master_speedup", self.master_speedup)?;
        self.base_throughput_kbps =
            ini.get_or(S, "base_throughput_kbps", self.base_throughput_kbps)?;
        self.erasure_prob = ini.get_or(S, "erasure_prob", self.erasure_prob)?;
        self.header_overhead = ini.get_or(S, "header_overhead", self.header_overhead)?;
        self.mem_overhead_factor =
            ini.get_or(S, "mem_overhead_factor", self.mem_overhead_factor)?;
        if let Some(s) = ini.get(S, "generator") {
            self.generator = s.parse()?;
        }
        if let Some(s) = ini.get(S, "delta") {
            self.delta = if s.eq_ignore_ascii_case("auto") { None } else { Some(s.parse()?) };
        }
        if let Some(s) = ini.get(S, "setup_cost") {
            self.setup_cost = s.parse()?;
        }
        self.client_fraction = ini.get_or(S, "client_fraction", self.client_fraction)?;
        self.c_up_fraction = ini.get_or(S, "c_up_fraction", self.c_up_fraction)?;
        self.epsilon = ini.get_or(S, "epsilon", self.epsilon)?;
        if let Some(s) = ini.get(S, "participation") {
            self.participation = s.parse()?;
        }
        if let Some(s) = ini.get(S, "data_mode") {
            self.data_mode = s.parse()?;
        }
        self.trace_points = ini.get_or(S, "trace_points", self.trace_points)?;
        self.agg_fanin = ini.get_or(S, "agg_fanin", self.agg_fanin)?;
        self.ladder_tiers = ini.get_or(S, "ladder_tiers", self.ladder_tiers)?;
        self.seed = ini.get_or(S, "seed", self.seed)?;
        if let Some(s) = ini.get(S, "artifacts_dir") {
            self.artifacts_dir = if s.is_empty() { None } else { Some(s.to_string()) };
        }
        self.validate()
    }

    /// Sanity-check parameter ranges.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n_devices > 0, "n_devices must be > 0");
        anyhow::ensure!(self.model_dim > 0, "model_dim must be > 0");
        anyhow::ensure!(self.points_per_device > 0, "points_per_device must be > 0");
        anyhow::ensure!((0.0..1.0).contains(&self.nu_comp), "nu_comp in [0,1)");
        anyhow::ensure!((0.0..1.0).contains(&self.nu_link), "nu_link in [0,1)");
        anyhow::ensure!((0.0..1.0).contains(&self.erasure_prob), "erasure_prob in [0,1)");
        anyhow::ensure!(self.learning_rate > 0.0, "learning_rate must be > 0");
        anyhow::ensure!(self.base_mac_rate_kmacs > 0.0, "base_mac_rate_kmacs must be > 0");
        anyhow::ensure!(self.base_throughput_kbps > 0.0, "base_throughput_kbps must be > 0");
        if let Some(d) = self.delta {
            anyhow::ensure!((0.0..=1.0).contains(&d), "delta in [0,1]");
        }
        anyhow::ensure!(
            self.client_fraction > 0.0 && self.client_fraction <= 1.0,
            "client_fraction in (0,1]"
        );
        match self.participation {
            Participation::All => {}
            Participation::Fraction(f) => {
                anyhow::ensure!(f > 0.0 && f <= 1.0, "participation frac in (0,1]");
            }
            Participation::Count(k) => {
                anyhow::ensure!(k > 0, "participation count must be > 0");
            }
        }
        anyhow::ensure!(
            self.participation == Participation::All || self.client_fraction >= 1.0,
            "participation and client_fraction are alternative spellings of per-epoch \
             sampling; set only one (client_fraction = {}, participation = {:?})",
            self.client_fraction,
            self.participation
        );
        anyhow::ensure!(
            self.trace_points == 0 || self.trace_points >= 2,
            "trace_points must be 0 (unbounded) or ≥ 2"
        );
        anyhow::ensure!(self.agg_fanin != 1, "agg_fanin must be 0 (flat) or ≥ 2");
        // per-rung ladders underflow f64 at huge fleet sizes: the slowest
        // device's rate (1−ν)^(n−1)·base hits 0, its delay becomes
        // infinite, and the Eq. 16 bracket search can never cover m.
        // Those configs already fail today (deep in the optimizer);
        // reject them up front with the fix spelled out.
        if self.ladder_tiers == 0 && self.n_devices > 1 {
            let steps = (self.n_devices - 1) as f64;
            for (name, nu) in [("nu_comp", self.nu_comp), ("nu_link", self.nu_link)] {
                if nu > 0.0 {
                    anyhow::ensure!(
                        steps * -(1.0 - nu).ln() <= 700.0,
                        "{name}={nu} over {} devices underflows the per-device \
                         heterogeneity ladder (slowest rate rounds to 0); set \
                         ladder_tiers (e.g. 24) to tile the ladder instead",
                        self.n_devices
                    );
                }
            }
        }
        Ok(())
    }
}
