//! Experiment configuration: typed config structs + an INI-style parser.
//!
//! No `serde`/`toml` in the offline sandbox, so configs are a small
//! line-oriented format (`key = value`, `[section]` headers, `#` comments)
//! parsed by [`ini::Ini`]. [`ExperimentConfig`] holds every knob of the
//! paper's §IV setup with the paper's values as defaults, so
//! `ExperimentConfig::paper()` *is* the published experiment.

mod experiment;
mod ini;

pub use experiment::{
    DataMode, ExperimentConfig, GeneratorKind, Participation, SetupCostKind, ShardingKind,
};
pub use ini::Ini;

#[cfg(test)]
mod tests;
