use super::*;

#[test]
fn ini_parses_sections_keys_comments() {
    let doc = Ini::parse(
        "# top comment\n\
         root_key = 1\n\
         [experiment]\n\
         n_devices = 12   # trailing comment\n\
         sharding = powerlaw:1.5\n\
         \n\
         [other]\n\
         x = hello world\n",
    )
    .unwrap();
    assert_eq!(doc.get("", "root_key"), Some("1"));
    assert_eq!(doc.get("experiment", "n_devices"), Some("12"));
    assert_eq!(doc.get("other", "x"), Some("hello world"));
    assert_eq!(doc.get("missing", "x"), None);
    let mut sections: Vec<_> = doc.sections().collect();
    sections.sort();
    assert_eq!(sections, vec!["", "experiment", "other"]);
}

#[test]
fn ini_rejects_bad_lines() {
    assert!(Ini::parse("just a line").is_err());
    assert!(Ini::parse("[unterminated").is_err());
}

#[test]
fn ini_typed_get_or() {
    let doc = Ini::parse("[s]\na = 2.5\nb = oops\n").unwrap();
    assert_eq!(doc.get_or("s", "a", 0.0).unwrap(), 2.5);
    assert_eq!(doc.get_or::<f64>("s", "missing", 7.0).unwrap(), 7.0);
    assert!(doc.get_or("s", "b", 0.0).is_err());
}

#[test]
fn ini_get_list_splits_and_trims() {
    let doc = Ini::parse("[sweep]\nnu_comp = 0, 0.1 ,0.2\nempty_tail = a,,b,\n").unwrap();
    assert_eq!(doc.get_list("sweep", "nu_comp").unwrap(), vec!["0", "0.1", "0.2"]);
    assert_eq!(doc.get_list("sweep", "empty_tail").unwrap(), vec!["a", "b"]);
    assert_eq!(doc.get_list("sweep", "missing"), None);
}

#[test]
fn ini_duplicate_key_last_wins() {
    let doc = Ini::parse("[s]\nk = 1\nk = 2\n").unwrap();
    assert_eq!(doc.get("s", "k"), Some("2"));
}

#[test]
fn paper_config_matches_section_iv() {
    let c = ExperimentConfig::paper();
    assert_eq!(c.n_devices, 24);
    assert_eq!(c.points_per_device, 300);
    assert_eq!(c.model_dim, 500);
    assert_eq!(c.snr_db, 0.0);
    assert_eq!(c.learning_rate, 0.0085);
    assert_eq!(c.base_mac_rate_kmacs, 1536.0);
    assert_eq!(c.master_speedup, 10.0);
    assert_eq!(c.base_throughput_kbps, 216.0);
    assert_eq!(c.erasure_prob, 0.1);
    assert_eq!(c.total_points(), 7200);
    c.validate().unwrap();
}

#[test]
fn apply_ini_overrides_and_validates() {
    let mut c = ExperimentConfig::paper();
    let ini = Ini::parse(
        "[experiment]\nn_devices = 8\ndelta = 0.13\ngenerator = bernoulli\nsharding = dirichlet:0.5\n",
    )
    .unwrap();
    c.apply_ini(&ini).unwrap();
    assert_eq!(c.n_devices, 8);
    assert_eq!(c.delta, Some(0.13));
    assert_eq!(c.generator, GeneratorKind::Bernoulli);
    assert!(matches!(c.sharding, ShardingKind::Dirichlet(a) if (a - 0.5).abs() < 1e-12));
    // untouched keys keep paper defaults
    assert_eq!(c.model_dim, 500);
}

#[test]
fn apply_ini_rejects_invalid() {
    let mut c = ExperimentConfig::paper();
    let ini = Ini::parse("[experiment]\nnu_comp = 1.5\n").unwrap();
    assert!(c.apply_ini(&ini).is_err());
}

#[test]
fn delta_auto_resets_to_optimizer() {
    let mut c = ExperimentConfig::paper();
    c.delta = Some(0.2);
    let ini = Ini::parse("[experiment]\ndelta = auto\n").unwrap();
    c.apply_ini(&ini).unwrap();
    assert_eq!(c.delta, None);
}

#[test]
fn sharding_parse_errors() {
    assert!("powerlaw:abc".parse::<ShardingKind>().is_err());
    assert!("nope".parse::<ShardingKind>().is_err());
    assert!("equal".parse::<ShardingKind>().is_ok());
}

#[test]
fn generator_parse_aliases() {
    assert_eq!("normal".parse::<GeneratorKind>().unwrap(), GeneratorKind::Gaussian);
    assert_eq!("rademacher".parse::<GeneratorKind>().unwrap(), GeneratorKind::Bernoulli);
}

#[test]
fn participation_parse_and_validate() {
    assert_eq!("all".parse::<Participation>().unwrap(), Participation::All);
    assert_eq!("frac:0.25".parse::<Participation>().unwrap(), Participation::Fraction(0.25));
    assert_eq!("count:256".parse::<Participation>().unwrap(), Participation::Count(256));
    assert!("frac:".parse::<Participation>().is_err());
    assert!("half".parse::<Participation>().is_err());

    let mut c = ExperimentConfig::small();
    c.participation = Participation::Fraction(1.5);
    assert!(c.validate().is_err());
    c.participation = Participation::Count(0);
    assert!(c.validate().is_err());
    c.participation = Participation::Count(3);
    c.validate().unwrap();
    // the legacy spelling and the new one cannot be combined
    c.client_fraction = 0.5;
    assert!(c.validate().is_err());
}

#[test]
fn sampled_per_epoch_resolves_and_clamps() {
    let mut c = ExperimentConfig::small(); // 8 devices
    assert_eq!(c.sampled_per_epoch(), 8);
    c.participation = Participation::Count(3);
    assert_eq!(c.sampled_per_epoch(), 3);
    c.participation = Participation::Count(99);
    assert_eq!(c.sampled_per_epoch(), 8);
    c.participation = Participation::Fraction(0.5);
    assert_eq!(c.sampled_per_epoch(), 4);
    c.participation = Participation::Fraction(1.0);
    assert_eq!(c.sampled_per_epoch(), 8);
    c.participation = Participation::All;
    c.client_fraction = 0.25;
    assert_eq!(c.sampled_per_epoch(), 2);
}

#[test]
fn scale_knobs_apply_ini_and_validate() {
    let mut c = ExperimentConfig::small();
    let ini = Ini::parse(
        "[experiment]\nparticipation = count:4\ndata_mode = lean\ntrace_points = 64\n\
         agg_fanin = 32\nladder_tiers = 24\n",
    )
    .unwrap();
    c.apply_ini(&ini).unwrap();
    assert_eq!(c.participation, Participation::Count(4));
    assert_eq!(c.data_mode, DataMode::Lean);
    assert_eq!(c.trace_points, 64);
    assert_eq!(c.agg_fanin, 32);
    assert_eq!(c.ladder_tiers, 24);

    let mut bad = ExperimentConfig::small();
    bad.trace_points = 1;
    assert!(bad.validate().is_err());
    bad.trace_points = 0;
    bad.agg_fanin = 1;
    assert!(bad.validate().is_err());
}

#[test]
fn ladder_underflow_rejected_without_tiers() {
    let mut c = ExperimentConfig::small();
    c.n_devices = 100_000;
    c.points_per_device = 4;
    c.nu_comp = 0.2;
    // per-device rungs: (1−0.2)^99999 underflows f64 → rejected up front
    let err = c.validate().unwrap_err().to_string();
    assert!(err.contains("ladder_tiers"), "unexpected error: {err}");
    // tiling the ladder makes the same fleet valid
    c.ladder_tiers = 24;
    c.validate().unwrap();
}
