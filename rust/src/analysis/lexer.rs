//! A small Rust lexer for the lint engine — tokens, not syntax trees.
//!
//! The vendored-deps constraint rules out `syn`/`proc-macro2`, so the
//! rule engine works on a token stream produced here. The lexer's one
//! job is to be *literal-aware*: rule patterns must never fire on text
//! inside comments, doc comments (and therefore doctests), string
//! literals, raw strings, byte strings, or char literals — and must
//! still fire inside macro bodies, which are lexed like any other code.
//!
//! Covered Rust surface:
//!
//! * line comments (`//`, `///`, `//!`) and **nested** block comments
//!   (`/* /* */ */`), captured as [`Comment`] records so the rule layer
//!   can parse `cfl-lint: allow(...)` suppressions and check for
//!   justifying comments (rule R6);
//! * string literals with escapes, raw strings `r"…"` / `r#"…"#` with
//!   any number of hashes, byte strings `b"…"` and raw byte strings
//!   `br#"…"#`;
//! * char literals (`'a'`, `'\n'`, `b'\0'`) distinguished from
//!   lifetimes (`'static`, `'_`) by lookahead — the classic tick
//!   ambiguity;
//! * raw identifiers (`r#type` lexes as the identifier `type`);
//! * numeric literals (decimal, `0x`/`0o`/`0b`, underscores, float
//!   fractions and signed exponents, type suffixes), classified
//!   [`TokKind::Int`] vs [`TokKind::Float`] — rule R5 needs to spot a
//!   hard-coded integer seed;
//! * identifiers and punctuation, with `::` fused into one token so
//!   path patterns like `Instant::now` are three tokens, not four.
//!
//! Positions are 1-based `(line, col)` in characters; every finding the
//! rule layer reports points back at these spans.

/// Token classification — just enough structure for lexical rules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `Instant`, `unwrap`, …).
    Ident,
    /// Integer literal (`42`, `0xF1EE7`, `1_000u64`).
    Int,
    /// Float literal (`0.5`, `1e-3`, `2.5f32`).
    Float,
    /// String literal of any flavor (escaped, raw, byte); text is the
    /// literal body, escapes left as written.
    Str,
    /// Char or byte-char literal (`'a'`, `'\n'`, `b'x'`).
    Char,
    /// Lifetime (`'static` lexes with text `static`).
    Lifetime,
    /// Punctuation. One char per token, except `::` which is fused.
    Punct,
}

/// One lexed token with its 1-based source position.
#[derive(Clone, Debug, PartialEq)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// One comment (line or block), recorded at its starting position.
/// `text` keeps the interior verbatim (without the `//` introducer for
/// line comments; with delimiters for block comments).
#[derive(Clone, Debug, PartialEq)]
pub struct Comment {
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// Lexer output: code tokens plus the comment side-channel.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

struct Cursor {
    chars: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
}

impl Cursor {
    fn new(src: &str) -> Self {
        Self { chars: src.chars().collect(), i: 0, line: 1, col: 1 }
    }

    fn peek(&self, k: usize) -> Option<char> {
        self.chars.get(self.i + k).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.i).copied()?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_continue(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

/// Lex `src` into tokens and comments. Never fails: malformed input
/// (unterminated strings/comments) is tolerated by consuming to EOF —
/// a linter must keep going on files that don't compile yet.
pub fn lex(src: &str) -> Lexed {
    let mut cur = Cursor::new(src);
    let mut out = Lexed::default();
    while let Some(c) = cur.peek(0) {
        let (line, col) = (cur.line, cur.col);
        if c.is_whitespace() {
            cur.bump();
            continue;
        }
        // comments first: `//…\n` and nested `/* … */`
        if c == '/' && cur.peek(1) == Some('/') {
            cur.bump();
            cur.bump();
            let mut text = String::new();
            while let Some(ch) = cur.peek(0) {
                if ch == '\n' {
                    break;
                }
                text.push(ch);
                cur.bump();
            }
            out.comments.push(Comment { text, line, col });
            continue;
        }
        if c == '/' && cur.peek(1) == Some('*') {
            cur.bump();
            cur.bump();
            let mut depth = 1usize;
            let mut text = String::from("/*");
            while depth > 0 {
                match (cur.peek(0), cur.peek(1)) {
                    (Some('/'), Some('*')) => {
                        depth += 1;
                        text.push_str("/*");
                        cur.bump();
                        cur.bump();
                    }
                    (Some('*'), Some('/')) => {
                        depth -= 1;
                        text.push_str("*/");
                        cur.bump();
                        cur.bump();
                    }
                    (Some(ch), _) => {
                        text.push(ch);
                        cur.bump();
                    }
                    (None, _) => break, // unterminated — tolerate at EOF
                }
            }
            out.comments.push(Comment { text, line, col });
            continue;
        }
        // raw strings / raw identifiers: r"…", r#"…"#, r#ident
        if c == 'r' {
            if let Some(hashes) = raw_string_hashes(&cur, 1) {
                cur.bump(); // r
                let body = raw_string_body(&mut cur, hashes);
                out.toks.push(Tok { kind: TokKind::Str, text: body, line, col });
                continue;
            }
            if cur.peek(1) == Some('#') && cur.peek(2).is_some_and(is_ident_start) {
                cur.bump(); // r
                cur.bump(); // #
                let name = ident_body(&mut cur);
                out.toks.push(Tok { kind: TokKind::Ident, text: name, line, col });
                continue;
            }
        }
        // byte literals: b'…', b"…", br"…", br#"…"#
        if c == 'b' {
            match cur.peek(1) {
                Some('\'') => {
                    cur.bump(); // b
                    let body = char_literal_body(&mut cur);
                    out.toks.push(Tok { kind: TokKind::Char, text: body, line, col });
                    continue;
                }
                Some('"') => {
                    cur.bump(); // b
                    let body = string_body(&mut cur);
                    out.toks.push(Tok { kind: TokKind::Str, text: body, line, col });
                    continue;
                }
                Some('r') => {
                    if let Some(hashes) = raw_string_hashes(&cur, 2) {
                        cur.bump(); // b
                        cur.bump(); // r
                        let body = raw_string_body(&mut cur, hashes);
                        out.toks.push(Tok { kind: TokKind::Str, text: body, line, col });
                        continue;
                    }
                }
                _ => {}
            }
        }
        if is_ident_start(c) {
            let name = ident_body(&mut cur);
            out.toks.push(Tok { kind: TokKind::Ident, text: name, line, col });
            continue;
        }
        if c.is_ascii_digit() {
            let (text, kind) = number_body(&mut cur);
            out.toks.push(Tok { kind, text, line, col });
            continue;
        }
        if c == '"' {
            let body = string_body(&mut cur);
            out.toks.push(Tok { kind: TokKind::Str, text: body, line, col });
            continue;
        }
        if c == '\'' {
            // lifetime iff the tick is followed by an identifier char
            // that is NOT itself closed by a tick ('a' is a char, 'a is
            // a lifetime); escapes are always chars
            let c1 = cur.peek(1);
            let lifetime = match c1 {
                Some('\\') => false,
                Some(ch) if is_ident_continue(ch) => cur.peek(2) != Some('\''),
                _ => false,
            };
            if lifetime {
                cur.bump(); // '
                let name = ident_body(&mut cur);
                out.toks.push(Tok { kind: TokKind::Lifetime, text: name, line, col });
            } else {
                let body = char_literal_body(&mut cur);
                out.toks.push(Tok { kind: TokKind::Char, text: body, line, col });
            }
            continue;
        }
        // punctuation; fuse `::` into one token for path patterns
        cur.bump();
        if c == ':' && cur.peek(0) == Some(':') {
            cur.bump();
            out.toks.push(Tok { kind: TokKind::Punct, text: "::".into(), line, col });
        } else {
            out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line, col });
        }
    }
    out
}

/// If the cursor (at offset `at` past the current position, i.e. just
/// after the `r`) sits on `#*k "`, return `Some(k)` — a raw string
/// opener. `at` is 1 for `r…`, 2 for `br…`.
fn raw_string_hashes(cur: &Cursor, at: usize) -> Option<usize> {
    let mut k = 0usize;
    while cur.peek(at + k) == Some('#') {
        k += 1;
    }
    (cur.peek(at + k) == Some('"')).then_some(k)
}

/// Consume `#*k " … " #*k` with the cursor just after the `r`.
fn raw_string_body(cur: &mut Cursor, hashes: usize) -> String {
    for _ in 0..hashes {
        cur.bump(); // opening #
    }
    cur.bump(); // opening "
    let mut body = String::new();
    loop {
        match cur.peek(0) {
            None => break, // unterminated — tolerate
            Some('"') => {
                let closes = (0..hashes).all(|k| cur.peek(1 + k) == Some('#'));
                if closes {
                    cur.bump();
                    for _ in 0..hashes {
                        cur.bump();
                    }
                    break;
                }
                body.push('"');
                cur.bump();
            }
            Some(ch) => {
                body.push(ch);
                cur.bump();
            }
        }
    }
    body
}

/// Consume a `"…"` string (cursor on the opening quote), escapes kept
/// verbatim in the returned body.
fn string_body(cur: &mut Cursor) -> String {
    cur.bump(); // "
    let mut body = String::new();
    loop {
        match cur.peek(0) {
            None => break,
            Some('\\') => {
                body.push('\\');
                cur.bump();
                if let Some(e) = cur.peek(0) {
                    body.push(e);
                    cur.bump();
                }
            }
            Some('"') => {
                cur.bump();
                break;
            }
            Some(ch) => {
                body.push(ch);
                cur.bump();
            }
        }
    }
    body
}

/// Consume a `'…'` char literal (cursor on the opening tick).
fn char_literal_body(cur: &mut Cursor) -> String {
    cur.bump(); // '
    let mut body = String::new();
    loop {
        match cur.peek(0) {
            None => break,
            Some('\\') => {
                body.push('\\');
                cur.bump();
                if let Some(e) = cur.peek(0) {
                    body.push(e);
                    cur.bump();
                }
            }
            Some('\'') => {
                cur.bump();
                break;
            }
            Some(ch) => {
                body.push(ch);
                cur.bump();
            }
        }
    }
    body
}

fn ident_body(cur: &mut Cursor) -> String {
    let mut name = String::new();
    while let Some(ch) = cur.peek(0) {
        if !is_ident_continue(ch) {
            break;
        }
        name.push(ch);
        cur.bump();
    }
    name
}

/// Consume a numeric literal (cursor on the first digit). Underscores,
/// radix prefixes, fraction (`.` must be followed by a digit so ranges
/// `1..n` and tuple fields stay punctuation), signed exponents, and
/// type suffixes are all folded into one token.
fn number_body(cur: &mut Cursor) -> (String, TokKind) {
    let radix_prefixed = cur.peek(0) == Some('0')
        && matches!(cur.peek(1), Some('x' | 'X' | 'o' | 'O' | 'b' | 'B'));
    let mut text = String::new();
    let mut float = false;
    let consume_run = |cur: &mut Cursor, text: &mut String| {
        while let Some(ch) = cur.peek(0) {
            if !(ch.is_ascii_alphanumeric() || ch == '_') {
                break;
            }
            text.push(ch);
            cur.bump();
        }
    };
    consume_run(&mut *cur, &mut text);
    loop {
        // signed exponent: `1e-3`, `2.5E+8` (never in radix-prefixed)
        if !radix_prefixed
            && (text.ends_with('e') || text.ends_with('E'))
            && matches!(cur.peek(0), Some('+' | '-'))
            && cur.peek(1).is_some_and(|d| d.is_ascii_digit())
        {
            float = true;
            text.push(cur.bump().unwrap_or('+'));
            consume_run(&mut *cur, &mut text);
            continue;
        }
        // fraction: a dot is part of the number only when a digit follows
        if !radix_prefixed
            && cur.peek(0) == Some('.')
            && cur.peek(1).is_some_and(|d| d.is_ascii_digit())
        {
            float = true;
            text.push('.');
            cur.bump();
            consume_run(&mut *cur, &mut text);
            continue;
        }
        break;
    }
    if !radix_prefixed && !float {
        // unsigned exponent inside the run (`1e3`) is a float too
        // (char-closure patterns, not `[char; N]` ones — those need 1.71
        // and the MSRV is 1.70)
        float = text.contains('.')
            || (text.chars().next().is_some_and(|c| c.is_ascii_digit())
                && !text.chars().any(|c| matches!(c, 'u' | 'i' | 'f'))
                && text.chars().filter(|c| matches!(c, 'e' | 'E')).count() == 1
                && text
                    .split(|c: char| matches!(c, 'e' | 'E'))
                    .nth(1)
                    .is_some_and(|exp| !exp.is_empty() && exp.chars().all(|c| c.is_ascii_digit())));
    }
    (text, if float { TokKind::Float } else { TokKind::Int })
}
