use super::lexer::{lex, TokKind};
use super::rules::{check_source, classify, FileClass, META_BAD, META_STALE};
use super::{default_roots, render_json, render_text, run_paths, Finding, Report};
use std::path::PathBuf;

// ------------------------------------------------------------- lexer

#[test]
fn lexes_paths_with_fused_colons() {
    let l = lex("std::time::Instant::now()");
    let texts: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
    assert_eq!(texts, ["std", "::", "time", "::", "Instant", "::", "now", "(", ")"]);
    assert_eq!(l.toks[4].col, 12, "Instant starts at column 12");
}

#[test]
fn masks_string_and_char_literals() {
    // Instant::now inside a string must produce zero Ident tokens
    let l = lex(r#"let s = "Instant::now()"; let c = 'I';"#);
    assert!(l.toks.iter().all(|t| t.text != "Instant"));
    assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Str).count(), 1);
    assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Char).count(), 1);
}

#[test]
fn raw_strings_with_hashes_are_opaque() {
    let src = "let s = r##\"quote \"# unwrap() here\"##; done";
    let l = lex(src);
    let strs: Vec<&str> =
        l.toks.iter().filter(|t| t.kind == TokKind::Str).map(|t| t.text.as_str()).collect();
    assert_eq!(strs, ["quote \"# unwrap() here"]);
    assert!(l.toks.iter().any(|t| t.text == "done"), "lexing continues after the raw string");
    assert!(l.toks.iter().all(|t| t.text != "unwrap"));
}

#[test]
fn byte_and_raw_byte_strings() {
    let l = lex(r##"let a = b"bytes"; let b = br#"raw "q" bytes"#; let c = b'x';"##);
    let strs: Vec<&str> =
        l.toks.iter().filter(|t| t.kind == TokKind::Str).map(|t| t.text.as_str()).collect();
    assert_eq!(strs, ["bytes", r#"raw "q" bytes"#]);
    assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Char).count(), 1);
}

#[test]
fn nested_block_comments_terminate_correctly() {
    let l = lex("/* outer /* inner */ still outer */ code");
    assert_eq!(l.comments.len(), 1);
    assert!(l.comments[0].text.contains("inner"));
    let texts: Vec<&str> = l.toks.iter().map(|t| t.text.as_str()).collect();
    assert_eq!(texts, ["code"]);
}

#[test]
fn line_comments_capture_text_and_position() {
    let l = lex("let x = 1; // trailing note\n// standalone\nlet y = 2;");
    assert_eq!(l.comments.len(), 2);
    assert_eq!(l.comments[0].text, " trailing note");
    assert_eq!((l.comments[0].line, l.comments[1].line), (1, 2));
}

#[test]
fn char_vs_lifetime_ticks() {
    let l = lex(r"fn f<'a>(x: &'a str) -> char { let c = 'a'; let n = '\n'; c.max(n) }");
    let lifetimes: Vec<&str> =
        l.toks.iter().filter(|t| t.kind == TokKind::Lifetime).map(|t| t.text.as_str()).collect();
    assert_eq!(lifetimes, ["a", "a"]);
    assert_eq!(l.toks.iter().filter(|t| t.kind == TokKind::Char).count(), 2);
}

#[test]
fn raw_identifiers_lex_as_plain_idents() {
    let l = lex("let r#type = 1; r#fn();");
    let idents: Vec<&str> =
        l.toks.iter().filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str()).collect();
    assert_eq!(idents, ["let", "type", "fn"]);
}

#[test]
fn numeric_literal_shapes() {
    let l = lex("1_000u64 0xFF_u8 1e-3 2.5f32 1..n x.0.time 0b1010");
    let kinds: Vec<(TokKind, &str)> = l
        .toks
        .iter()
        .filter(|t| matches!(t.kind, TokKind::Int | TokKind::Float))
        .map(|t| (t.kind, t.text.as_str()))
        .collect();
    assert_eq!(
        kinds,
        [
            (TokKind::Int, "1_000u64"),
            (TokKind::Int, "0xFF_u8"),
            (TokKind::Float, "1e-3"),
            (TokKind::Float, "2.5f32"),
            (TokKind::Int, "1"),
            (TokKind::Int, "0"),
            (TokKind::Int, "0b1010"),
        ]
    );
    // the range dots and field-access dots stay punctuation
    assert_eq!(l.toks.iter().filter(|t| t.text == ".").count(), 4);
}

#[test]
fn macro_bodies_are_lexed_like_code() {
    // rules must see through macro invocations — a violation inside
    // obs_event!/format! arguments is still a violation
    let l = lex(r#"obs_event!(Info, "epoch_done", t = Instant::now());"#);
    let idents: Vec<&str> =
        l.toks.iter().filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str()).collect();
    assert!(idents.contains(&"Instant") && idents.contains(&"now"));
}

#[test]
fn unterminated_input_is_tolerated() {
    // a linter must not hang or panic on files that don't compile
    lex("let s = \"unterminated");
    lex("/* unterminated comment");
    lex("let s = r#\"unterminated raw");
}

// ---------------------------------------------------------- classify

#[test]
fn classifies_by_tree_position() {
    assert_eq!(classify("rust/src/des/sim.rs"), (FileClass::Src, "des/sim.rs".into()));
    assert_eq!(classify("/abs/repo/rust/src/obs/mod.rs"), (FileClass::Src, "obs/mod.rs".into()));
    assert_eq!(classify("rust/src/des/tests.rs"), (FileClass::SrcTest, "des/tests.rs".into()));
    assert_eq!(classify("rust/benches/fig1.rs").0, FileClass::Bench);
    assert_eq!(classify("rust/tests/cli_integration.rs").0, FileClass::IntegrationTest);
    assert_eq!(classify("examples/quickstart.rs").0, FileClass::Example);
    // unknown paths (lint fixtures, ad-hoc files) get the strict class
    assert_eq!(classify("/tmp/fixture.rs").0, FileClass::Src);
}

// ------------------------------------------------------------- rules
//
// Fixture convention: two positive and two negative sources per rule,
// checked for the exact rule id and the file:line span of the hit.

fn rule_hits<'a>(findings: &'a [Finding], rule: &str) -> Vec<&'a Finding> {
    findings.iter().filter(|f| f.rule == rule).collect()
}

fn assert_fires(path: &str, src: &str, rule: &str, line: u32) {
    let findings = check_source(path, src);
    let hits = rule_hits(&findings, rule);
    assert!(
        hits.iter().any(|f| f.line == line && f.file == path),
        "expected {rule} at {path}:{line}, got {findings:?}"
    );
}

fn assert_silent(path: &str, src: &str, rule: &str) {
    let findings = check_source(path, src);
    let hits = rule_hits(&findings, rule);
    assert!(hits.is_empty(), "expected no {rule} in {path}, got {hits:?}");
}

// R1 no-wall-clock ---------------------------------------------------

const R1_POS_DES: &str = "use std::time::Instant;\nfn q() -> f64 {\n    let t = Instant::now();\n    t.elapsed().as_secs_f64()\n}\n";
const R1_POS_SYS: &str = "fn stamp() -> u64 {\n    let t = std::time::SystemTime::now();\n    0\n}\n";

#[test]
fn r1_fires_on_wall_clock_in_sim_code() {
    assert_fires("rust/src/des/clock.rs", R1_POS_DES, "no-wall-clock", 3);
    assert_fires("rust/src/coordinator/sim.rs", R1_POS_SYS, "no-wall-clock", 2);
}

#[test]
fn r1_silent_in_wall_clock_modules_and_tests() {
    // obs owns wall time; unit tests may time things freely
    assert_silent("rust/src/obs/phase.rs", R1_POS_DES, "no-wall-clock");
    assert_silent("rust/src/des/tests.rs", R1_POS_DES, "no-wall-clock");
}

// R2 no-raw-print ----------------------------------------------------

const R2_POS_EPRINT: &str = "fn progress(i: usize) {\n    eprintln!(\"scenario {i} done\");\n}\n";
const R2_POS_PRINT: &str = "fn table() {\n    println!(\"col\");\n}\n";

#[test]
fn r2_fires_on_raw_print_in_library_code() {
    assert_fires("rust/src/sweep/report.rs", R2_POS_EPRINT, "no-raw-print", 2);
    assert_fires("rust/src/data/mod.rs", R2_POS_PRINT, "no-raw-print", 2);
}

#[test]
fn r2_silent_in_cli_and_obs_sinks() {
    assert_silent("rust/src/main.rs", R2_POS_PRINT, "no-raw-print");
    assert_silent("rust/src/obs/sink.rs", R2_POS_EPRINT, "no-raw-print");
}

// R3 no-panic-paths --------------------------------------------------

const R3_POS_UNWRAP: &str = "fn read(b: &[u8]) -> u32 {\n    u32::from_le_bytes(b.try_into().unwrap())\n}\n";
const R3_POS_PANIC: &str = "fn agg(n: usize) {\n    if n == 0 {\n        panic!(\"empty gather\");\n    }\n}\n";

#[test]
fn r3_fires_in_fleet_paths() {
    assert_fires("rust/src/transport/wire.rs", R3_POS_UNWRAP, "no-panic-paths", 2);
    assert_fires("rust/src/coordinator/agg.rs", R3_POS_PANIC, "no-panic-paths", 3);
}

#[test]
fn r3_scoped_to_fleet_modules_and_spares_unwrap_or() {
    // linalg is pure compute — panics there fail fast in tests, not fleets
    assert_silent("rust/src/linalg/mod.rs", R3_POS_UNWRAP, "no-panic-paths");
    let unwrap_or = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap_or(0)\n}\n";
    assert_silent("rust/src/transport/wire.rs", unwrap_or, "no-panic-paths");
}

#[test]
fn r3_skips_inline_test_modules() {
    let src = "fn ok() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        Some(1).unwrap();\n    }\n}\n";
    assert_silent("rust/src/transport/wire.rs", src, "no-panic-paths");
}

// R4 total-float-order -----------------------------------------------

const R4_POS: &str = "fn m(v: &mut [f64]) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";

#[test]
fn r4_fires_everywhere_including_tests() {
    assert_fires("rust/src/stats/mod.rs", R4_POS, "total-float-order", 2);
    // tests are in scope — a NaN panic in a comparator is the PR 5 bug
    assert_fires("rust/src/simnet/tests.rs", R4_POS, "total-float-order", 2);
}

#[test]
fn r4_spares_trait_impls_and_total_cmp() {
    let impl_def = "impl PartialOrd for E {\n    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {\n        Some(self.cmp(o))\n    }\n}\n";
    assert_silent("rust/src/des/sim.rs", impl_def, "total-float-order");
    let total = "fn m(v: &mut [f64]) {\n    v.sort_by(|a, b| a.total_cmp(b));\n}\n";
    assert_silent("rust/src/stats/mod.rs", total, "total-float-order");
}

// R5 seeded-rng ------------------------------------------------------

#[test]
fn r5_fires_on_entropy_and_literal_seeds() {
    let entropy = "fn f() -> u64 {\n    let mut r = thread_rng();\n    r.next_u64()\n}\n";
    assert_fires("rust/src/fl/mod.rs", entropy, "seeded-rng", 2);
    let literal = "fn f() -> Rng {\n    Rng::new(42)\n}\n";
    assert_fires("rust/src/data/mod.rs", literal, "seeded-rng", 2);
}

#[test]
fn r5_spares_mix_seed_derivation_and_test_seeds() {
    let derived = "fn f(root: u64) -> Rng {\n    Rng::new(mix_seed(root, 3))\n}\n";
    assert_silent("rust/src/data/mod.rs", derived, "seeded-rng");
    // pinned seeds are the whole point of unit tests
    let literal = "fn f() -> Rng {\n    Rng::new(7)\n}\n";
    assert_silent("rust/src/data/tests.rs", literal, "seeded-rng");
}

// R6 atomic-ordering-audit -------------------------------------------

#[test]
fn r6_fires_on_unjustified_and_relaxed_outside_obs() {
    // a comment is not enough for Relaxed outside obs/ — only an allow is
    let relaxed = "fn stop(s: &AtomicBool) {\n    // fine, single writer\n    s.store(true, Ordering::Relaxed);\n}\n";
    assert_fires("rust/src/transport/state.rs", relaxed, "atomic-ordering-audit", 3);
    let bare = "fn get(s: &AtomicU64) -> u64 {\n\n\n\n\n    s.load(Ordering::Acquire)\n}\n";
    assert_fires("rust/src/sweep/runner.rs", bare, "atomic-ordering-audit", 6);
}

#[test]
fn r6_accepts_comments_near_and_relaxed_in_obs() {
    let justified = "fn get(s: &AtomicU64) -> u64 {\n    // pairs with the Release store in install()\n    s.load(Ordering::Acquire)\n}\n";
    assert_silent("rust/src/sweep/runner.rs", justified, "atomic-ordering-audit");
    let obs = "fn bump(c: &AtomicU64) {\n    // monotonic counter, no ordering needed\n    c.fetch_add(1, Ordering::Relaxed);\n}\n";
    assert_silent("rust/src/obs/metrics.rs", obs, "atomic-ordering-audit");
}

// ------------------------------------------------------ suppressions

#[test]
fn trailing_allow_suppresses_and_is_marked_used() {
    let src = "fn q() -> Instant {\n    Instant::now() // cfl-lint: allow(no-wall-clock) — calibration probe\n}\n";
    let findings = check_source("rust/src/des/clock.rs", src);
    assert!(findings.is_empty(), "allow must suppress and not go stale: {findings:?}");
}

#[test]
fn standalone_allow_covers_the_next_code_line() {
    let src = "fn q() -> Instant {\n    // cfl-lint: allow(no-wall-clock) — calibration probe\n    Instant::now()\n}\n";
    let findings = check_source("rust/src/des/clock.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn stale_allow_is_a_finding() {
    let src = "fn q() -> u32 {\n    1 // cfl-lint: allow(no-wall-clock) — nothing here violates it\n}\n";
    let findings = check_source("rust/src/des/clock.rs", src);
    let hits = rule_hits(&findings, META_STALE);
    assert_eq!(hits.len(), 1, "{findings:?}");
    assert_eq!(hits[0].line, 2);
}

#[test]
fn allow_without_reason_or_with_unknown_rule_is_malformed() {
    let no_reason = "fn q() -> Instant {\n    Instant::now() // cfl-lint: allow(no-wall-clock)\n}\n";
    let findings = check_source("rust/src/des/clock.rs", no_reason);
    assert_eq!(rule_hits(&findings, META_BAD).len(), 1, "{findings:?}");
    // the unsuppressed finding itself must survive
    assert_eq!(rule_hits(&findings, "no-wall-clock").len(), 1);

    let unknown = "fn q() -> u32 {\n    1 // cfl-lint: allow(no-such-rule) — typo\n}\n";
    let findings = check_source("rust/src/des/clock.rs", unknown);
    assert_eq!(rule_hits(&findings, META_BAD).len(), 1, "{findings:?}");
}

#[test]
fn prose_mentioning_the_syntax_is_inert() {
    let src = "// suppressions use cfl-lint: allow(<rule>) with a reason\nfn ok() {}\n";
    let findings = check_source("rust/src/des/clock.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn allow_in_a_string_literal_is_inert() {
    let src = "fn doc() -> &'static str {\n    \"// cfl-lint: allow(no-wall-clock) — example\"\n}\n";
    let findings = check_source("rust/src/des/clock.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
}

// ---------------------------------------------------------- frontend

#[test]
fn json_rendering_is_line_oriented_with_summary_tail() {
    let src = "fn q() -> f64 {\n    let t = Instant::now();\n    0.0\n}\n";
    let report = Report { findings: check_source("rust/src/des/clock.rs", src), files: 1 };
    assert_eq!(report.findings.len(), 1);
    let json = render_json(&report);
    let lines: Vec<&str> = json.lines().collect();
    assert_eq!(lines.len(), 2);
    assert!(lines[0].starts_with("{\"kind\":\"finding\",\"rule\":\"no-wall-clock\""));
    assert!(lines[0].contains("\"file\":\"rust/src/des/clock.rs\",\"line\":2"));
    assert!(lines[1].starts_with("{\"kind\":\"summary\","));
    assert!(lines[1].contains("\"findings\":1"));
    let text = render_text(&report);
    assert!(text.contains("rust/src/des/clock.rs:2:"), "{text}");
}

#[test]
fn unknown_rule_filter_is_an_error() {
    let err = run_paths(&[PathBuf::from("rust/src")], Some("no-such-rule"));
    assert!(err.is_err());
}

/// The quick-tier gate: the repo's own tree must lint clean on every
/// `cargo test`. This is the enforcement point ISSUE 9 asks for — CI
/// and scripts/check.sh call `cfl lint` too, but this test makes the
/// invariant unskippable locally.
#[test]
fn repo_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let roots: Vec<PathBuf> = default_roots().iter().map(|p| root.join(p)).collect();
    let report = run_paths(&roots, None).expect("walking the repo tree");
    assert!(report.files > 50, "walked only {} files — wrong root?", report.files);
    assert!(report.clean(), "repo has lint findings:\n{}", render_text(&report));
}
