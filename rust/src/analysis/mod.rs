//! Repo-native static analysis: `cfl lint`.
//!
//! The conformance suite (sim-vs-live byte identity, resume identity)
//! only stays green if a handful of invariants hold *everywhere* in the
//! tree: no wall-clock reads in simulated-time code, no unseeded
//! randomness, total float ordering, panic-free fleet loops, audited
//! atomics, and all diagnostics routed through the obs sinks. Clippy
//! can't express those — they're about *this* repo's module boundaries
//! — and the vendored-deps constraint rules out syn-based custom lints.
//! So this module hand-rolls the check: a literal-aware lexer
//! ([`lexer`]) feeds token-pattern rules ([`rules`]) with per-rule
//! scoping and a reason-mandatory suppression syntax.
//!
//! Entry points: the `cfl lint` subcommand, `scripts/check.sh`, a CI
//! step, and a quick-tier test that lints the repo on every
//! `cargo test`. All four fail on any finding, including stale allows.

pub mod lexer;
pub mod rules;

#[cfg(test)]
mod tests;

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use rules::{check_source, classify, FileClass, Finding, RuleInfo, RULES};

/// Outcome of a lint run.
#[derive(Debug, Default)]
pub struct Report {
    /// All findings, grouped per file in walk order (files sorted,
    /// findings line-ordered within a file) — deterministic output for
    /// identical trees.
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files: usize,
}

impl Report {
    /// Findings that are stale or malformed suppressions.
    pub fn allow_problems(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.rule == rules::META_STALE || f.rule == rules::META_BAD)
            .count()
    }

    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// The tree `cfl lint` covers when no paths are given, relative to the
/// repo root: library + binary sources, figure benches, integration
/// tests, and examples.
pub fn default_roots() -> Vec<PathBuf> {
    ["rust/src", "rust/benches", "rust/tests", "examples"]
        .iter()
        .map(PathBuf::from)
        .collect()
}

/// Lint every `.rs` file under `roots` (files are taken as-is,
/// directories walked recursively; `target/`, `vendor/`, and `.git/`
/// are skipped). `rule` restricts reporting to one rule id.
pub fn run_paths(roots: &[PathBuf], rule: Option<&str>) -> Result<Report> {
    if let Some(id) = rule {
        let known = RULES.iter().any(|r| r.id == id)
            || id == rules::META_STALE
            || id == rules::META_BAD;
        if !known {
            let ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
            bail!("unknown rule '{id}' (rules: {})", ids.join(", "));
        }
    }
    let mut files = Vec::new();
    for root in roots {
        collect_rs(root, &mut files)
            .with_context(|| format!("walking {}", root.display()))?;
    }
    files.sort();
    files.dedup();

    let mut report = Report::default();
    for path in &files {
        let src = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let display = path.to_string_lossy().replace('\\', "/");
        let mut findings = check_source(&display, &src);
        if let Some(id) = rule {
            findings.retain(|f| f.rule == id);
        }
        report.findings.extend(findings);
        report.files += 1;
    }
    Ok(report)
}

const SKIP_DIRS: &[&str] = &["target", "vendor", ".git"];

fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(());
    }
    if !root.is_dir() {
        bail!("{} is neither a file nor a directory (run from the repo root?)", root.display());
    }
    let mut entries: Vec<PathBuf> = fs::read_dir(root)
        .with_context(|| format!("listing {}", root.display()))?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if SKIP_DIRS.contains(&name) {
                continue;
            }
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Human-readable rendering: one `file:line:col  [rule] message` row
/// per finding plus a summary tail.
pub fn render_text(report: &Report) -> String {
    let mut s = String::new();
    for f in &report.findings {
        s.push_str(&format!("{}:{}:{}  [{}] {}\n", f.file, f.line, f.col, f.rule, f.message));
    }
    s.push_str(&format!(
        "cfl lint: {} finding(s) ({} allow problem(s)) across {} file(s), {} rule(s)\n",
        report.findings.len(),
        report.allow_problems(),
        report.files,
        RULES.len(),
    ));
    s
}

/// Machine-readable rendering: JSONL, one `{"kind":"finding",…}` object
/// per finding and a final `{"kind":"summary",…}` line — the same
/// line-oriented shape `scripts/bench_smoke.sh` greps, so shell checks
/// stay one-line.
pub fn render_json(report: &Report) -> String {
    use crate::sweep::json::escape;
    let mut s = String::new();
    for f in &report.findings {
        s.push_str(&format!(
            "{{\"kind\":\"finding\",\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"col\":{},\"message\":\"{}\"}}\n",
            escape(f.rule),
            escape(&f.file),
            f.line,
            f.col,
            escape(&f.message),
        ));
    }
    s.push_str(&format!(
        "{{\"kind\":\"summary\",\"files\":{},\"rules\":{},\"findings\":{},\"stale_allows\":{}}}\n",
        report.files,
        RULES.len(),
        report.findings.len(),
        report.allow_problems(),
    ));
    s
}
