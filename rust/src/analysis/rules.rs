//! The lint rules and the per-file engine that runs them.
//!
//! Each rule is a token-pattern check over [`super::lexer`] output —
//! deliberately lexical, not syntactic: no type information, no name
//! resolution. The rules are therefore written so that their patterns
//! are unambiguous at the token level (`Instant :: now`, `. unwrap (`),
//! and anything genuinely ambiguous (slice indexing, trait-dispatched
//! calls) stays out of scope; see docs/ANALYSIS.md for the rationale.
//!
//! Suppression: a finding is silenced by an allow comment naming the
//! rule, with a mandatory reason —
//!
//! ```text
//! let t = Instant::now(); // cfl-lint: allow(no-wall-clock) — calibration reads the host clock
//! ```
//!
//! A standalone allow comment on its own line targets the next code
//! line. Allows that suppress nothing are themselves findings
//! (`stale-allow`), as are allows that don't parse or name an unknown
//! rule (`bad-allow`) — suppressions must never rot silently.

use super::lexer::{lex, Comment, Tok, TokKind};

/// One confirmed lint finding (or a meta finding about an allow).
#[derive(Clone, Debug)]
pub struct Finding {
    /// Rule id (`no-wall-clock`, …) or the meta ids `stale-allow` /
    /// `bad-allow`.
    pub rule: &'static str,
    /// Display path, as walked (repo-relative when invoked from the
    /// repo root).
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub message: String,
}

/// Static description of one rule, for `--help`-style listings and docs.
pub struct RuleInfo {
    pub id: &'static str,
    pub summary: &'static str,
}

/// The rule set, in reporting order. Ids are what `--rule` and
/// `allow(...)` accept.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "no-wall-clock",
        summary: "Instant::now/SystemTime banned outside genuinely wall-clock modules",
    },
    RuleInfo {
        id: "no-raw-print",
        summary: "println!/eprintln! only in main.rs, cli/, obs/; use obs_event! elsewhere",
    },
    RuleInfo {
        id: "no-panic-paths",
        summary: "no unwrap/expect/panic! in transport/, coordinator/, sweep/runner non-test code",
    },
    RuleInfo {
        id: "total-float-order",
        summary: "float comparisons use total_cmp, never partial_cmp().unwrap()",
    },
    RuleInfo {
        id: "seeded-rng",
        summary: "RNG seeds derive from rng::mix_seed; no entropy sources, no literal seeds",
    },
    RuleInfo {
        id: "atomic-ordering-audit",
        summary: "every atomic Ordering:: use carries a justifying comment; Relaxed only under obs/",
    },
];

/// Meta rule ids (reported by the engine itself, not listed in [`RULES`]).
pub const META_STALE: &str = "stale-allow";
pub const META_BAD: &str = "bad-allow";

fn known_rule(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

/// How a file participates in linting, derived from its path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FileClass {
    /// Library/binary source under `rust/src/` (or any unrecognized
    /// path — unknown files get the strictest treatment, which is what
    /// makes lint fixtures in temp dirs behave like production code).
    Src,
    /// Unit-test source: `tests.rs` files and `tests/` dirs under src.
    SrcTest,
    /// `rust/benches/` — figure runners that print tables by design.
    Bench,
    /// `examples/` — user-facing demos.
    Example,
    /// `rust/tests/` — integration tests driving the built binary.
    IntegrationTest,
}

/// Classify a path and compute the module-relative path used by the
/// per-rule allowlists (for src files: the part after `rust/src/`).
pub fn classify(path: &str) -> (FileClass, String) {
    let norm = path.replace('\\', "/");
    if let Some(rel) = subpath(&norm, "rust/src/") {
        let class = if rel.ends_with("/tests.rs") || rel == "tests.rs" || rel.contains("/tests/") {
            FileClass::SrcTest
        } else {
            FileClass::Src
        };
        return (class, rel.to_string());
    }
    if let Some(rel) = subpath(&norm, "rust/benches/") {
        return (FileClass::Bench, rel.to_string());
    }
    if let Some(rel) = subpath(&norm, "rust/tests/") {
        return (FileClass::IntegrationTest, rel.to_string());
    }
    if let Some(rel) = subpath(&norm, "examples/") {
        return (FileClass::Example, rel.to_string());
    }
    (FileClass::Src, norm)
}

/// If `norm` contains the directory marker `base` (anchored at the
/// start or at a `/` boundary), return the path after it.
fn subpath<'a>(norm: &'a str, base: &str) -> Option<&'a str> {
    if let Some(rest) = norm.strip_prefix(base) {
        return Some(rest);
    }
    let marker = format!("/{base}");
    norm.find(&marker).map(|i| &norm[i + marker.len()..])
}

/// Lint one file's source text. `display` is the path reported in
/// findings; classification runs on the same string.
pub fn check_source(display: &str, src: &str) -> Vec<Finding> {
    let (class, rel) = classify(display);
    let lexed = lex(src);
    let test_regions = inline_test_regions(&lexed.toks);
    let (mut allows, mut findings) = parse_allows(&lexed.comments, &lexed.toks);

    let ctx = Ctx { toks: &lexed.toks, comments: &lexed.comments, class, rel: &rel };
    let mut candidates = Vec::new();
    candidates.extend(no_wall_clock(&ctx));
    candidates.extend(no_raw_print(&ctx));
    candidates.extend(no_panic_paths(&ctx));
    candidates.extend(total_float_order(&ctx));
    candidates.extend(seeded_rng(&ctx));
    candidates.extend(atomic_ordering_audit(&ctx));

    for cand in candidates {
        // unit-test code inside a `#[cfg(test)] mod` of a src file is
        // held to test rules, not production rules
        if cand.skip_in_tests
            && test_regions.iter().any(|&(lo, hi)| (lo..=hi).contains(&cand.line))
        {
            continue;
        }
        let mut suppressed = false;
        for allow in allows.iter_mut() {
            if allow.rule == cand.rule && allow.target == cand.line {
                allow.used = true;
                suppressed = true;
            }
        }
        if !suppressed {
            findings.push(Candidate::into_finding(cand));
        }
    }
    for allow in &allows {
        if !allow.used {
            findings.push(Finding {
                rule: META_STALE,
                file: String::new(),
                line: allow.comment_line,
                col: allow.comment_col,
                message: format!(
                    "allow({}) suppresses nothing on line {} — remove it",
                    allow.rule, allow.target
                ),
            });
        }
    }
    findings.sort_by(|a, b| (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule)));
    for f in &mut findings {
        f.file = display.to_string();
    }
    findings
}

struct Ctx<'a> {
    toks: &'a [Tok],
    comments: &'a [Comment],
    class: FileClass,
    rel: &'a str,
}

struct Candidate {
    rule: &'static str,
    line: u32,
    col: u32,
    message: String,
    /// Findings of most rules don't apply inside inline `#[cfg(test)]`
    /// modules of src files; rules that hold even in tests clear this.
    skip_in_tests: bool,
}

impl Candidate {
    fn into_finding(c: Candidate) -> Finding {
        Finding { rule: c.rule, file: String::new(), line: c.line, col: c.col, message: c.message }
    }
}

fn is_ident(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

fn is_punct(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

fn path_under(rel: &str, prefixes: &[&str], exact: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p)) || exact.iter().any(|e| rel == *e)
}

// ---------------------------------------------------------------- R1

/// Modules that legitimately read the host clock: observability (it
/// owns wall time), live-coordinator calibration/deadlines, transport
/// socket timeouts, the sweep worker's per-scenario timing, conformance
/// check timing, and the CLI itself.
const WALL_CLOCK_OK_PREFIXES: &[&str] = &["obs/", "cli/", "transport/", "conformance/"];
const WALL_CLOCK_OK_EXACT: &[&str] = &["main.rs", "coordinator/live.rs", "sweep/runner.rs"];

fn no_wall_clock(ctx: &Ctx) -> Vec<Candidate> {
    if ctx.class != FileClass::Src
        || path_under(ctx.rel, WALL_CLOCK_OK_PREFIXES, WALL_CLOCK_OK_EXACT)
    {
        return Vec::new();
    }
    let mut out = Vec::new();
    let t = ctx.toks;
    for i in 0..t.len() {
        let hit = if is_ident(&t[i], "SystemTime") {
            Some("SystemTime")
        } else if is_ident(&t[i], "Instant")
            && t.get(i + 1).is_some_and(|n| is_punct(n, "::"))
            && t.get(i + 2).is_some_and(|n| is_ident(n, "now"))
        {
            Some("Instant::now")
        } else {
            None
        };
        if let Some(what) = hit {
            out.push(Candidate {
                rule: "no-wall-clock",
                line: t[i].line,
                col: t[i].col,
                message: format!(
                    "{what} in simulated-time code — time this via obs::phase (or allow with a reason)"
                ),
                skip_in_tests: true,
            });
        }
    }
    out
}

// ---------------------------------------------------------------- R2

const RAW_PRINT_MACROS: &[&str] = &["println", "eprintln", "print", "eprint", "dbg"];

fn no_raw_print(ctx: &Ctx) -> Vec<Candidate> {
    if ctx.class != FileClass::Src
        || path_under(ctx.rel, &["cli/", "obs/"], &["main.rs"])
    {
        return Vec::new();
    }
    let mut out = Vec::new();
    let t = ctx.toks;
    for i in 0..t.len() {
        if t[i].kind == TokKind::Ident
            && RAW_PRINT_MACROS.contains(&t[i].text.as_str())
            && t.get(i + 1).is_some_and(|n| is_punct(n, "!"))
        {
            out.push(Candidate {
                rule: "no-raw-print",
                line: t[i].line,
                col: t[i].col,
                message: format!(
                    "{}! bypasses the obs sinks — emit an obs_event! so --log-level governs it",
                    t[i].text
                ),
                skip_in_tests: true,
            });
        }
    }
    out
}

// ---------------------------------------------------------------- R3

/// Long-running fleet paths where a panic kills a whole run: the
/// transport layer, both coordinators, and the sweep worker pool.
const PANIC_FREE_PREFIXES: &[&str] = &["transport/", "coordinator/"];
const PANIC_FREE_EXACT: &[&str] = &["sweep/runner.rs"];
const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

fn no_panic_paths(ctx: &Ctx) -> Vec<Candidate> {
    if ctx.class != FileClass::Src
        || !path_under(ctx.rel, PANIC_FREE_PREFIXES, PANIC_FREE_EXACT)
    {
        return Vec::new();
    }
    let mut out = Vec::new();
    let t = ctx.toks;
    for i in 0..t.len() {
        let (line, col, msg) = if (is_ident(&t[i], "unwrap") || is_ident(&t[i], "expect"))
            && i > 0
            && (is_punct(&t[i - 1], ".") || is_punct(&t[i - 1], "::"))
            && t.get(i + 1).is_some_and(|n| is_punct(n, "("))
        {
            (
                t[i].line,
                t[i].col,
                format!(".{}() in a fleet path — return an anyhow error instead", t[i].text),
            )
        } else if t[i].kind == TokKind::Ident
            && PANIC_MACROS.contains(&t[i].text.as_str())
            && t.get(i + 1).is_some_and(|n| is_punct(n, "!"))
        {
            (
                t[i].line,
                t[i].col,
                format!("{}! in a fleet path — return an anyhow error instead", t[i].text),
            )
        } else {
            continue;
        };
        out.push(Candidate {
            rule: "no-panic-paths",
            line,
            col,
            message: msg,
            skip_in_tests: true,
        });
    }
    out
}

// ---------------------------------------------------------------- R4

fn total_float_order(ctx: &Ctx) -> Vec<Candidate> {
    // applies everywhere, tests and benches included: a NaN-ordering
    // panic in a test comparator is exactly the bug PR 5 fixed
    let mut out = Vec::new();
    let t = ctx.toks;
    for i in 0..t.len() {
        if is_ident(&t[i], "partial_cmp")
            && i > 0
            && (is_punct(&t[i - 1], ".") || is_punct(&t[i - 1], "::"))
        {
            out.push(Candidate {
                rule: "total-float-order",
                line: t[i].line,
                col: t[i].col,
                message: "partial_cmp on floats is not total — use f64::total_cmp".into(),
                skip_in_tests: false,
            });
        }
    }
    out
}

// ---------------------------------------------------------------- R5

const ENTROPY_IDENTS: &[&str] =
    &["thread_rng", "ThreadRng", "OsRng", "from_entropy", "getrandom", "SystemRandom"];

fn seeded_rng(ctx: &Ctx) -> Vec<Candidate> {
    let mut out = Vec::new();
    let t = ctx.toks;
    for i in 0..t.len() {
        // entropy sources are banned everywhere, tests included —
        // a nondeterministic test is a flaky test
        if t[i].kind == TokKind::Ident && ENTROPY_IDENTS.contains(&t[i].text.as_str()) {
            out.push(Candidate {
                rule: "seeded-rng",
                line: t[i].line,
                col: t[i].col,
                message: format!(
                    "{} is an entropy source — all randomness must flow from the run seed",
                    t[i].text
                ),
                skip_in_tests: false,
            });
            continue;
        }
        // hard-coded seeds in production code hide stream collisions;
        // derive every stream with rng::mix_seed (tests may pin seeds)
        if ctx.class == FileClass::Src
            && is_ident(&t[i], "Rng")
            && t.get(i + 1).is_some_and(|n| is_punct(n, "::"))
            && t.get(i + 2).is_some_and(|n| is_ident(n, "new"))
            && t.get(i + 3).is_some_and(|n| is_punct(n, "("))
            && t.get(i + 4).is_some_and(|n| n.kind == TokKind::Int)
        {
            let lit = &t[i + 4];
            out.push(Candidate {
                rule: "seeded-rng",
                line: lit.line,
                col: lit.col,
                message: format!(
                    "hard-coded RNG seed {} — derive the stream with rng::mix_seed",
                    lit.text
                ),
                skip_in_tests: true,
            });
        }
    }
    out
}

// ---------------------------------------------------------------- R6

const ATOMIC_ORDERINGS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];
/// How close (in lines above) a justifying comment must sit.
const JUSTIFY_WINDOW: u32 = 3;

fn atomic_ordering_audit(ctx: &Ctx) -> Vec<Candidate> {
    if ctx.class != FileClass::Src {
        return Vec::new();
    }
    let in_obs = ctx.rel.starts_with("obs/");
    let mut out = Vec::new();
    let t = ctx.toks;
    for i in 0..t.len() {
        if !(is_ident(&t[i], "Ordering")
            && t.get(i + 1).is_some_and(|n| is_punct(n, "::"))
            && t.get(i + 2)
                .is_some_and(|n| n.kind == TokKind::Ident && ATOMIC_ORDERINGS.contains(&n.text.as_str())))
        {
            continue;
        }
        let variant = &t[i + 2];
        if variant.text == "Relaxed" && !in_obs {
            // Relaxed outside the obs counters is suspicious enough
            // that a nearby comment doesn't clear it: force an allow
            // so the reason is machine-checked against the rule id
            out.push(Candidate {
                rule: "atomic-ordering-audit",
                line: variant.line,
                col: variant.col,
                message: "Ordering::Relaxed outside obs/ — justify with an explicit allow".into(),
                skip_in_tests: true,
            });
            continue;
        }
        let justified = ctx.comments.iter().any(|c| {
            c.line == variant.line
                || (c.line < variant.line && variant.line - c.line <= JUSTIFY_WINDOW)
        });
        if !justified {
            out.push(Candidate {
                rule: "atomic-ordering-audit",
                line: variant.line,
                col: variant.col,
                message: format!(
                    "Ordering::{} without a justifying comment within {JUSTIFY_WINDOW} lines",
                    variant.text
                ),
                skip_in_tests: true,
            });
        }
    }
    out
}

// ------------------------------------------------------- suppressions

struct Allow {
    rule: String,
    /// Line this allow suppresses findings on.
    target: u32,
    comment_line: u32,
    comment_col: u32,
    used: bool,
}

/// Parse `cfl-lint: allow(<rule>) — <reason>` comments. Returns the
/// well-formed allows plus `bad-allow` findings for the rest. Only
/// comments that *start* with the marker count (after stripping doc
/// slashes/bangs), so prose that merely mentions the syntax is inert.
fn parse_allows(comments: &[Comment], toks: &[Tok]) -> (Vec<Allow>, Vec<Finding>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    for c in comments {
        // closure patterns, not `[char; N]` ones (those need 1.71; MSRV 1.70)
        let body = c.text.trim_start_matches(|ch: char| matches!(ch, '/' | '*' | '!' | ' ' | '\t'));
        let Some(rest) = body.strip_prefix("cfl-lint") else { continue };
        let mut err = |msg: String| {
            bad.push(Finding {
                rule: META_BAD,
                file: String::new(),
                line: c.line,
                col: c.col,
                message: msg,
            });
        };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix(':') else {
            err("malformed suppression — expected `cfl-lint: allow(<rule>) — <reason>`".into());
            continue;
        };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix("allow(") else {
            err("malformed suppression — expected `allow(<rule>)` after `cfl-lint:`".into());
            continue;
        };
        let Some(close) = rest.find(')') else {
            err("malformed suppression — unclosed `allow(`".into());
            continue;
        };
        let rule = rest[..close].trim().to_string();
        if !known_rule(&rule) {
            err(format!("allow names unknown rule `{rule}`"));
            continue;
        }
        let reason = rest[close + 1..]
            .trim_start_matches(|ch: char| matches!(ch, ' ' | '\t' | '—' | '–' | '-' | ':'))
            .trim();
        if reason.is_empty() {
            err(format!("allow({rule}) has no reason — say why the rule doesn't apply here"));
            continue;
        }
        // trailing comment suppresses its own line; a standalone
        // comment line suppresses the next line with code on it
        let target = if toks.iter().any(|t| t.line == c.line) {
            c.line
        } else {
            toks.iter()
                .map(|t| t.line)
                .filter(|&l| l > c.line)
                .min()
                .unwrap_or(c.line)
        };
        allows.push(Allow {
            rule,
            target,
            comment_line: c.line,
            comment_col: c.col,
            used: false,
        });
    }
    (allows, bad)
}

// -------------------------------------------------- inline test mods

/// Line ranges of `#[cfg(test)] mod … { … }` blocks in src files
/// (tests that live inline rather than in a sibling `tests.rs`).
fn inline_test_regions(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if !is_cfg_test_attr(toks, i) {
            i += 1;
            continue;
        }
        let start_line = toks[i].line;
        let mut j = i + 7;
        // skip any further attributes stacked on the same item
        while j + 1 < toks.len() && is_punct(&toks[j], "#") && is_punct(&toks[j + 1], "[") {
            j = match skip_balanced(toks, j + 1, "[", "]") {
                Some(k) => k,
                None => return out, // unbalanced — give up quietly
            };
        }
        if toks.get(j).is_some_and(|t| is_ident(t, "pub")) {
            j += 1;
            if toks.get(j).is_some_and(|t| is_punct(t, "(")) {
                j = match skip_balanced(toks, j, "(", ")") {
                    Some(k) => k,
                    None => return out,
                };
            }
        }
        if toks.get(j).is_some_and(|t| is_ident(t, "mod"))
            && toks.get(j + 1).is_some_and(|t| t.kind == TokKind::Ident)
            && toks.get(j + 2).is_some_and(|t| is_punct(t, "{"))
        {
            match skip_balanced(toks, j + 2, "{", "}") {
                Some(k) => {
                    let end_line = toks[k - 1].line;
                    out.push((start_line, end_line));
                    i = k;
                    continue;
                }
                None => return out,
            }
        }
        i += 1;
    }
    out
}

fn is_cfg_test_attr(toks: &[Tok], i: usize) -> bool {
    i + 6 < toks.len()
        && is_punct(&toks[i], "#")
        && is_punct(&toks[i + 1], "[")
        && is_ident(&toks[i + 2], "cfg")
        && is_punct(&toks[i + 3], "(")
        && is_ident(&toks[i + 4], "test")
        && is_punct(&toks[i + 5], ")")
        && is_punct(&toks[i + 6], "]")
}

/// With `toks[at]` on the opening delimiter, return the index just past
/// its matching close (delimiters inside strings/chars are already
/// opaque tokens, so plain depth counting is sound).
fn skip_balanced(toks: &[Tok], at: usize, open: &str, close: &str) -> Option<usize> {
    let mut depth = 0usize;
    let mut k = at;
    while k < toks.len() {
        if is_punct(&toks[k], open) {
            depth += 1;
        } else if is_punct(&toks[k], close) {
            depth -= 1;
            if depth == 0 {
                return Some(k + 1);
            }
        }
        k += 1;
    }
    None
}
