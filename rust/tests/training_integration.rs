//! Cross-module integration: optimizer → coding → coordinator → metrics,
//! all on the native backend (no artifacts needed).

use cfl::config::{ExperimentConfig, GeneratorKind, ShardingKind};
use cfl::coordinator::SimCoordinator;
use cfl::lb::LoadPolicy;
use cfl::stats::Summary;

fn base_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::small();
    cfg.seed = seed;
    cfg
}

#[test]
fn cfl_beats_uncoded_under_heterogeneity() {
    // the paper's headline claim, at test scale: with heterogeneous compute
    // and links, CFL reaches the target NMSE in less simulated time
    let mut cfg = base_cfg(11);
    cfg.nu_comp = 0.3;
    cfg.nu_link = 0.3;
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    let coded = sim.train_cfl().unwrap();
    let uncoded = sim.train_uncoded().unwrap();
    let tc = coded.time_to(cfg.target_nmse).expect("coded converged");
    let tu = uncoded.time_to(cfg.target_nmse).expect("uncoded converged");
    assert!(
        tc < tu,
        "CFL ({tc:.1}s) should beat uncoded ({tu:.1}s) at ν=(0.3,0.3)"
    );
}

#[test]
fn coded_epochs_are_shorter_but_start_later() {
    let mut cfg = base_cfg(12);
    cfg.nu_comp = 0.2;
    cfg.nu_link = 0.2;
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    let coded = sim.train_cfl().unwrap();
    let uncoded = sim.train_uncoded().unwrap();
    let mut cs = Summary::new();
    cs.extend(&coded.epoch_times);
    let mut us = Summary::new();
    us.extend(&uncoded.epoch_times);
    assert!(
        cs.mean() < us.mean(),
        "deadline epochs ({:.2}s) should be shorter than wait-for-all ({:.2}s)",
        cs.mean(),
        us.mean()
    );
    assert!(coded.setup_secs > 0.0 && uncoded.setup_secs == 0.0);
}

#[test]
fn bernoulli_and_gaussian_codes_both_converge() {
    for kind in [GeneratorKind::Gaussian, GeneratorKind::Bernoulli] {
        let mut cfg = base_cfg(13);
        cfg.generator = kind;
        let mut sim = SimCoordinator::new(&cfg).unwrap();
        let run = sim.train_cfl().unwrap();
        assert!(run.converged.is_some(), "{kind:?} code failed to converge");
    }
}

#[test]
fn non_iid_sharding_trains() {
    for sharding in [ShardingKind::PowerLaw(1.2), ShardingKind::Dirichlet(0.5)] {
        let mut cfg = base_cfg(14);
        cfg.sharding = sharding;
        cfg.max_epochs = 6_000;
        let mut sim = SimCoordinator::new(&cfg).unwrap();
        let run = sim.train_cfl().unwrap();
        assert!(
            run.converged.is_some(),
            "{sharding:?} failed (final {:?})",
            run.trace.final_nmse()
        );
    }
}

#[test]
fn delta_sweep_orders_setup_cost() {
    // larger δ ⇒ more parity rows ⇒ strictly more setup bits and a later
    // training start (Fig. 2's initial offsets / Fig. 5 bottom)
    let mut prev_bits = 0.0;
    for &delta in &[0.05, 0.15, 0.25] {
        let mut cfg = base_cfg(15);
        cfg.delta = Some(delta);
        let mut sim = SimCoordinator::new(&cfg).unwrap();
        let run = sim.train_cfl().unwrap();
        assert!(run.parity_upload_bits > prev_bits, "parity bits must grow with δ");
        prev_bits = run.parity_upload_bits;
    }
}

#[test]
fn policy_round_trip_through_coordinator() {
    let cfg = base_cfg(16);
    let sim = SimCoordinator::new(&cfg).unwrap();
    let policy = sim.policy().unwrap();
    assert!(policy.parity_rows > 0);
    assert!(policy.epoch_deadline.is_finite());
    // uncoded policy from the same fleet
    let unc = LoadPolicy::uncoded(sim.fleet());
    assert_eq!(unc.device_loads.len(), cfg.n_devices);
}

#[test]
fn trace_is_monotone_in_time() {
    let mut sim = SimCoordinator::new(&base_cfg(17)).unwrap();
    for run in [sim.train_cfl().unwrap(), sim.train_uncoded().unwrap()] {
        let mut last = -1.0;
        for p in &run.trace.points {
            assert!(p.time_s > last, "time must strictly increase");
            last = p.time_s;
        }
    }
}

#[test]
fn homogeneous_fleet_gain_is_modest() {
    // Fig. 4 anchor: at ν = (0,0) the coding gain should be near 1 — far
    // smaller than the heterogeneous gain (asserted > 1 under ν=(0.3,0.3)
    // above). Allow slack: at test scale a single seed is noisy.
    let mut cfg = base_cfg(18);
    cfg.nu_comp = 0.0;
    cfg.nu_link = 0.0;
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    let coded = sim.train_cfl().unwrap();
    let uncoded = sim.train_uncoded().unwrap();
    if let (Some(tc), Some(tu)) = (coded.time_to(cfg.target_nmse), uncoded.time_to(cfg.target_nmse))
    {
        let gain = tu / tc;
        assert!(gain < 3.0, "homogeneous gain should be modest, got {gain:.2}");
    }
}

#[test]
fn client_selection_extension_converges() {
    // §V future-work: sample half the devices per epoch; the parity
    // gradient + inverse-probability weighting keep the estimate unbiased
    let mut cfg = base_cfg(19);
    cfg.client_fraction = 0.5;
    cfg.max_epochs = 8_000;
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    let run = sim.train_cfl().unwrap();
    assert!(
        run.converged.is_some(),
        "client-selection run failed (final {:?})",
        run.trace.final_nmse()
    );
}

#[test]
fn client_fraction_validated() {
    let mut cfg = base_cfg(20);
    cfg.client_fraction = 0.0;
    assert!(SimCoordinator::new(&cfg).is_err());
    cfg.client_fraction = 1.5;
    assert!(SimCoordinator::new(&cfg).is_err());
}
