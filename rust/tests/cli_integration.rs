//! End-to-end CLI integration: run the built `cfl` binary as a subprocess.

use std::process::Command;

fn cfl_bin() -> Option<std::path::PathBuf> {
    // cargo puts integration-test binaries in target/<profile>/deps; the
    // cli binary sits one level up.
    let mut path = std::env::current_exe().ok()?;
    path.pop();
    if path.ends_with("deps") {
        path.pop();
    }
    let bin = path.join("cfl");
    bin.exists().then_some(bin)
}

macro_rules! require_bin {
    () => {
        match cfl_bin() {
            Some(b) => b,
            None => {
                eprintln!("skipping: cfl binary not built (cargo build first)");
                return;
            }
        }
    };
}

#[test]
fn optimize_subcommand_prints_policy() {
    let bin = require_bin!();
    let out = Command::new(&bin).args(["optimize", "--seed", "5"]).output().unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("parity rows"), "{text}");
    assert!(text.contains("t* ="), "{text}");
    assert!(text.contains("P{{miss}}") || text.contains("P{miss}"), "{text}");
}

#[test]
fn train_subcommand_reports_gain_and_writes_traces() {
    let bin = require_bin!();
    let out_dir = std::env::temp_dir().join("cfl_cli_train");
    std::fs::remove_dir_all(&out_dir).ok();
    let out = Command::new(&bin)
        .args([
            "train",
            "--seed",
            "7",
            "--nu-comp",
            "0.3",
            "--nu-link",
            "0.3",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("LS bound"), "{text}");
    assert!(text.contains("uncoded"), "{text}");
    let cfl_csv = std::fs::read_to_string(out_dir.join("trace_cfl.csv")).unwrap();
    assert!(cfl_csv.starts_with("time_s,epoch,nmse"));
    assert!(cfl_csv.lines().count() > 10);
    std::fs::remove_dir_all(&out_dir).ok();
}

#[test]
fn bad_flag_fails_cleanly() {
    let bin = require_bin!();
    let out = Command::new(&bin).args(["train", "--bogus"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("bogus"), "{err}");
}

#[test]
fn config_file_round_trip() {
    let bin = require_bin!();
    let dir = std::env::temp_dir().join("cfl_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("exp.ini");
    std::fs::write(
        &cfg_path,
        "[experiment]\nn_devices = 6\npoints_per_device = 48\nmodel_dim = 24\nsnr_db = 10\n",
    )
    .unwrap();
    let out = Command::new(&bin)
        .args(["optimize", "--config", cfg_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("m = 288"), "config not applied: {text}");
    std::fs::remove_dir_all(&dir).ok();
}
