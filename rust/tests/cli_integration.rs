//! End-to-end CLI integration: run the built `cfl` binary as a subprocess.

use std::process::Command;

fn cfl_bin() -> Option<std::path::PathBuf> {
    // cargo puts integration-test binaries in target/<profile>/deps; the
    // cli binary sits one level up.
    let mut path = std::env::current_exe().ok()?;
    path.pop();
    if path.ends_with("deps") {
        path.pop();
    }
    let bin = path.join("cfl");
    bin.exists().then_some(bin)
}

macro_rules! require_bin {
    () => {
        match cfl_bin() {
            Some(b) => b,
            None => {
                eprintln!("skipping: cfl binary not built (cargo build first)");
                return;
            }
        }
    };
}

#[test]
fn optimize_subcommand_prints_policy() {
    let bin = require_bin!();
    let out = Command::new(&bin).args(["optimize", "--seed", "5"]).output().unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("parity rows"), "{text}");
    assert!(text.contains("t* ="), "{text}");
    assert!(text.contains("P{{miss}}") || text.contains("P{miss}"), "{text}");
}

#[test]
fn train_subcommand_reports_gain_and_writes_traces() {
    let bin = require_bin!();
    let out_dir = std::env::temp_dir().join("cfl_cli_train");
    std::fs::remove_dir_all(&out_dir).ok();
    let out = Command::new(&bin)
        .args([
            "train",
            "--seed",
            "7",
            "--nu-comp",
            "0.3",
            "--nu-link",
            "0.3",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("LS bound"), "{text}");
    assert!(text.contains("uncoded"), "{text}");
    let cfl_csv = std::fs::read_to_string(out_dir.join("trace_cfl.csv")).unwrap();
    assert!(cfl_csv.starts_with("time_s,epoch,nmse"));
    assert!(cfl_csv.lines().count() > 10);
    std::fs::remove_dir_all(&out_dir).ok();
}

#[test]
fn bad_flag_fails_cleanly() {
    let bin = require_bin!();
    let out = Command::new(&bin).args(["train", "--bogus"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("bogus"), "{err}");
}

#[test]
fn sweep_subcommand_expands_grid_and_parallel_matches_serial() {
    let bin = require_bin!();
    let dir = std::env::temp_dir().join("cfl_cli_sweep");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("sweep.ini");
    std::fs::write(
        &cfg_path,
        "[experiment]\nn_devices = 4\npoints_per_device = 16\nmodel_dim = 8\nsnr_db = 10\n\
         max_epochs = 300\ntarget_nmse = 2e-2\n\
         [sweep]\nnu_comp = 0, 0.2\nnu_link = 0, 0.2\n",
    )
    .unwrap();
    let run = |workers: &str, out: &std::path::Path| {
        Command::new(&bin)
            .args([
                "sweep",
                "--config",
                cfg_path.to_str().unwrap(),
                "--workers",
                workers,
                "--out",
                out.to_str().unwrap(),
                "--quiet",
            ])
            .output()
            .unwrap()
    };
    let (serial_dir, parallel_dir) = (dir.join("serial"), dir.join("parallel"));
    let serial = run("1", &serial_dir);
    assert!(serial.status.success(), "stderr: {}", String::from_utf8_lossy(&serial.stderr));
    let text = String::from_utf8_lossy(&serial.stdout);
    assert!(text.contains("2 axes → 4 scenarios"), "{text}");
    assert!(text.contains("coding gain matrix"), "{text}");

    let parallel = run("2", &parallel_dir);
    assert!(parallel.status.success(), "stderr: {}", String::from_utf8_lossy(&parallel.stderr));
    // parallel results are byte-identical to serial: stdout and reports
    assert_eq!(serial.stdout, parallel.stdout);
    for report in ["sweep_scenarios.csv", "sweep_report.json"] {
        let a = std::fs::read(serial_dir.join(report)).unwrap();
        let b = std::fs::read(parallel_dir.join(report)).unwrap();
        assert_eq!(a, b, "{report} differs between worker counts");
        assert!(!a.is_empty());
    }
    let csv = std::fs::read_to_string(serial_dir.join("sweep_scenarios.csv")).unwrap();
    assert!(csv.starts_with("scenario,nu_comp,nu_link,"), "{csv}");
    assert_eq!(csv.lines().count(), 1 + 4, "{csv}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_resume_zip_and_traces_roundtrip() {
    let bin = require_bin!();
    let dir = std::env::temp_dir().join("cfl_cli_sweep_resume");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let run = |out: &std::path::Path, extra: &[&str]| {
        let mut args = vec![
            "sweep",
            "--seed",
            "9",
            "--devices",
            "4",
            "--epochs",
            "60",
            "--target-nmse",
            "0",
            "--axis",
            "nu=0,0.2",
            "--axis",
            "delta=0.1,0.15",
            "--zip",
            "nu+delta",
            "--workers",
            "2",
            "--quiet",
            "--out",
        ];
        let out_str = out.to_str().unwrap();
        args.push(out_str);
        args.extend_from_slice(extra);
        Command::new(&bin).args(&args).output().unwrap()
    };

    // uninterrupted run, with per-scenario trace export
    let full_dir = dir.join("full");
    let traces_dir = dir.join("traces");
    let full = run(&full_dir, &["--traces-dir", traces_dir.to_str().unwrap()]);
    assert!(full.status.success(), "stderr: {}", String::from_utf8_lossy(&full.stderr));
    let text = String::from_utf8_lossy(&full.stdout);
    // zipped: 2 axes but only 2 scenarios, and the zip is announced
    assert!(text.contains("2 axes → 2 scenarios"), "{text}");
    assert!(text.contains("zip nu+delta"), "{text}");
    let full_csv = std::fs::read_to_string(full_dir.join("sweep_scenarios.csv")).unwrap();
    assert_eq!(full_csv.lines().count(), 1 + 2, "{full_csv}");
    // one cfl + one uncoded trace per scenario
    let mut traces: Vec<String> = std::fs::read_dir(&traces_dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    traces.sort();
    assert_eq!(traces.len(), 4, "{traces:?}");
    assert!(traces[0].ends_with("__cfl.csv"), "{traces:?}");

    // simulate a mid-run kill: keep the header + the first scenario row,
    // then resume — the merged CSV must match the uninterrupted run
    let resumed_dir = dir.join("resumed");
    std::fs::create_dir_all(&resumed_dir).unwrap();
    let kept: Vec<&str> = full_csv.lines().take(2).collect();
    let resumed_csv_path = resumed_dir.join("sweep_scenarios.csv");
    std::fs::write(&resumed_csv_path, format!("{}\n", kept.join("\n"))).unwrap();
    let resumed = run(&resumed_dir, &["--resume", resumed_csv_path.to_str().unwrap()]);
    assert!(resumed.status.success(), "stderr: {}", String::from_utf8_lossy(&resumed.stderr));
    let err = String::from_utf8_lossy(&resumed.stderr);
    assert!(err.contains("resume: 1 completed scenario(s) recovered"), "{err}");
    let resumed_csv = std::fs::read_to_string(&resumed_csv_path).unwrap();
    assert_eq!(full_csv, resumed_csv, "resumed CSV must be byte-identical");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn help_prints_without_failing() {
    let bin = require_bin!();
    let out = Command::new(&bin).args(["--help"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in ["sweep", "--axis", "--workers", "train", "optimize"] {
        assert!(text.contains(needle), "help missing {needle}: {text}");
    }
}

#[test]
fn config_file_round_trip() {
    let bin = require_bin!();
    let dir = std::env::temp_dir().join("cfl_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("exp.ini");
    std::fs::write(
        &cfg_path,
        "[experiment]\nn_devices = 6\npoints_per_device = 48\nmodel_dim = 24\nsnr_db = 10\n",
    )
    .unwrap();
    let out = Command::new(&bin)
        .args(["optimize", "--config", cfg_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("m = 288"), "config not applied: {text}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Sandboxes that deny loopback bind skip the socket tests silently.
fn loopback_ok() -> bool {
    match std::net::TcpListener::bind("127.0.0.1:0") {
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping: loopback bind denied ({e})");
            false
        }
    }
}

#[test]
fn serve_and_devices_train_over_tcp_loopback() {
    let bin = require_bin!();
    if !loopback_ok() {
        return;
    }
    let dir = std::env::temp_dir().join("cfl_cli_serve");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let port_file = dir.join("addr");

    let mut serve = Command::new(&bin)
        .args([
            "serve",
            "--bind",
            "127.0.0.1:0",
            "--port-file",
            port_file.to_str().unwrap(),
            "--devices",
            "2",
            "--epochs",
            "400",
            "--seed",
            "7",
            "--time-scale",
            "1e-4",
            "--skip-uncoded",
            "--check-nmse",
            "0.8",
            "--quiet",
        ])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();

    // wait for the coordinator to publish its ephemeral address
    let mut addr = String::new();
    for _ in 0..100 {
        if let Ok(s) = std::fs::read_to_string(&port_file) {
            if s.trim().parse::<std::net::SocketAddr>().is_ok() {
                addr = s.trim().to_string();
                break;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    assert!(!addr.is_empty(), "serve never published its address");

    let device = |id: &str| {
        Command::new(&bin)
            .args(["device", "--connect", &addr, "--id", id, "--quiet"])
            .spawn()
            .unwrap()
    };
    let mut d0 = device("0");
    let mut d1 = device("1");

    let serve_out = serve.wait_with_output().unwrap();
    let text = String::from_utf8_lossy(&serve_out.stdout);
    assert!(serve_out.status.success(), "serve failed: {text}");
    assert!(text.contains("check-nmse ok"), "{text}");
    // devices exit cleanly once the coordinator sends Shutdown
    assert!(d0.wait().unwrap().success());
    assert!(d1.wait().unwrap().success());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_live_tcp_spawns_real_device_processes() {
    let bin = require_bin!();
    if !loopback_ok() {
        return;
    }
    let dir = std::env::temp_dir().join("cfl_cli_sweep_tcp");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let out = Command::new(&bin)
        .args([
            "sweep",
            "--live",
            "--transport",
            "tcp",
            "--axis",
            "nu=0,0.2",
            "--devices",
            "3",
            "--epochs",
            "20",
            "--target-nmse",
            "0",
            "--time-scale",
            "1e-4",
            "--skip-uncoded",
            "--out",
            dir.to_str().unwrap(),
            "--quiet",
        ])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("cfl sweep (live)"), "{text}");
    let json = std::fs::read_to_string(dir.join("sweep_report.json")).unwrap();
    assert!(json.contains("\"backend\": \"live\""), "{json}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_check_gates_on_the_baseline() {
    let bin = require_bin!();
    let dir = std::env::temp_dir().join("cfl_cli_bench_check");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let baseline = dir.join("baseline.json");
    let report = dir.join("BENCH_ci.json");
    std::fs::write(&baseline, r#"{"scenarios": [{"id": "s0", "gain": 2.0, "wall_s": 1}]}"#)
        .unwrap();

    std::fs::write(&report, r#"{"scenarios": [{"id": "s0", "gain": 1.9, "wall_s": 2}]}"#).unwrap();
    let ok = Command::new(&bin)
        .args([
            "bench-check",
            "--report",
            report.to_str().unwrap(),
            "--baseline",
            baseline.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(ok.status.success(), "stderr: {}", String::from_utf8_lossy(&ok.stderr));
    assert!(String::from_utf8_lossy(&ok.stdout).contains("bench-check ok"));

    std::fs::write(&report, r#"{"scenarios": [{"id": "s0", "gain": 1.0, "wall_s": 2}]}"#).unwrap();
    let bad = Command::new(&bin)
        .args([
            "bench-check",
            "--report",
            report.to_str().unwrap(),
            "--baseline",
            baseline.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(!bad.status.success(), "a 50% gain drop must fail the check");
    let err = String::from_utf8_lossy(&bad.stderr);
    assert!(err.contains("regression"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn lint_subcommand_flags_violations_and_emits_json() {
    let bin = require_bin!();
    let dir = std::env::temp_dir().join("cfl_cli_lint");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    // paths outside the repo layout classify as production source (the
    // strictest class), so this fixture trips both no-wall-clock and
    // no-raw-print
    let bad = dir.join("bad.rs");
    std::fs::write(
        &bad,
        "fn f() {\n    let t = std::time::Instant::now();\n    println!(\"{t:?}\");\n}\n",
    )
    .unwrap();

    let out = Command::new(&bin).args(["lint", bad.to_str().unwrap()]).output().unwrap();
    assert!(!out.status.success(), "lint must exit nonzero on a violating file");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("no-wall-clock"), "{text}");
    assert!(text.contains("no-raw-print"), "{text}");
    assert!(text.contains(":2:"), "span for Instant::now must point at line 2: {text}");

    let out =
        Command::new(&bin).args(["lint", "--json", bad.to_str().unwrap()]).output().unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    let lines: Vec<&str> = text.lines().filter(|l| !l.is_empty()).collect();
    assert!(lines.len() >= 3, "two findings + summary expected: {text}");
    assert!(
        lines.iter().all(|l| l.starts_with('{') && l.ends_with('}')),
        "every line must be a JSON object: {text}"
    );
    assert!(lines[0].contains("\"kind\":\"finding\"") && lines[0].contains("\"line\":2"), "{text}");
    let last = lines.last().unwrap();
    assert!(last.contains("\"kind\":\"summary\"") && last.contains("\"files\":1"), "{text}");

    // --rule narrows the run to one rule's findings
    let out = Command::new(&bin)
        .args(["lint", "--rule", "no-raw-print", bad.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("no-raw-print"), "{text}");
    assert!(!text.contains("no-wall-clock"), "--rule must filter other rules: {text}");

    std::fs::remove_dir_all(&dir).ok();
}
