//! Integration: the PJRT artifact runtime against the native oracle, and
//! full training through the PJRT backend.
//!
//! Requires `make artifacts` (skips gracefully when absent so plain
//! `cargo test` works on hosts without python/jax).

use cfl::config::ExperimentConfig;
use cfl::coordinator::SimCoordinator;
use cfl::fl::{GradBackend, NativeBackend};
use cfl::linalg::Mat;
use cfl::rng::Rng;
use cfl::runtime::PjrtBackend;

fn artifacts_dir() -> Option<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then(|| dir.to_str().unwrap().to_string())
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn rel_err(a: &Mat, b: &Mat) -> f64 {
    (a.dist_sq(b) / b.norm_sq().max(1e-30)).sqrt()
}

#[test]
fn pjrt_partial_grad_matches_native() {
    let dir = require_artifacts!();
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut native = NativeBackend;
    let mut rng = Rng::new(1);
    // logical sizes below, equal to, and straddling the padded shapes
    for &(l, d) in &[(1usize, 1usize), (60, 40), (128, 128), (300, 500), (512, 512)] {
        let x = Mat::randn(l, d, &mut rng);
        let beta = Mat::randn(d, 1, &mut rng);
        let y = Mat::randn(l, 1, &mut rng);
        let got = pjrt.partial_grad(&x, &beta, &y).unwrap();
        let want = native.partial_grad(&x, &beta, &y).unwrap();
        assert_eq!(got.rows(), d);
        let err = rel_err(&got, &want);
        assert!(err < 1e-4, "L={l} D={d}: rel err {err:.2e}");
    }
}

#[test]
fn pjrt_parity_grad_matches_native() {
    let dir = require_artifacts!();
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut native = NativeBackend;
    let mut rng = Rng::new(2);
    for &(c_rows, d, c) in &[(64usize, 40usize, 64usize), (936, 500, 936), (2048, 512, 2000)] {
        let xt = Mat::randn(c_rows, d, &mut rng);
        let beta = Mat::randn(d, 1, &mut rng);
        let yt = Mat::randn(c_rows, 1, &mut rng);
        let got = pjrt.parity_grad(&xt, &beta, &yt, c).unwrap();
        let want = native.parity_grad(&xt, &beta, &yt, c).unwrap();
        let err = rel_err(&got, &want);
        assert!(err < 1e-4, "C={c_rows} D={d}: rel err {err:.2e}");
    }
}

#[test]
fn pjrt_encode_matches_native() {
    let dir = require_artifacts!();
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut native = NativeBackend;
    let mut rng = Rng::new(3);
    for &(c, l, d) in &[(16usize, 20usize, 8usize), (100, 128, 128), (400, 300, 500)] {
        let g = Mat::randn(c, l, &mut rng);
        let x = Mat::randn(l, d, &mut rng);
        let y = Mat::randn(l, 1, &mut rng);
        let w: Vec<f32> = (0..l).map(|i| 0.2 + 0.8 * (i as f32 / l as f32)).collect();
        let (gx, gy) = pjrt.encode(&g, &w, &x, &y).unwrap();
        let (nx, ny) = native.encode(&g, &w, &x, &y).unwrap();
        assert_eq!((gx.rows(), gx.cols()), (c, d));
        assert!(rel_err(&gx, &nx) < 1e-4, "X̃ mismatch at ({c},{l},{d})");
        assert!(rel_err(&gy, &ny) < 1e-4, "ỹ mismatch at ({c},{l},{d})");
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let dir = require_artifacts!();
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut rng = Rng::new(4);
    let x = Mat::randn(60, 40, &mut rng);
    let beta = Mat::randn(40, 1, &mut rng);
    let y = Mat::randn(60, 1, &mut rng);
    for _ in 0..5 {
        pjrt.partial_grad(&x, &beta, &y).unwrap();
    }
    assert_eq!(pjrt.executions, 5);
}

#[test]
fn full_cfl_training_through_pjrt() {
    let dir = require_artifacts!();
    let mut cfg = ExperimentConfig::small();
    cfg.artifacts_dir = Some(dir);
    cfg.max_epochs = 2_000;
    let mut sim = SimCoordinator::new(&cfg).unwrap();
    assert_eq!(sim.backend_name(), "pjrt");
    let run = sim.train_cfl().unwrap();
    assert!(
        run.converged.is_some(),
        "PJRT-backed CFL did not converge (final NMSE {:?})",
        run.trace.final_nmse()
    );
}

#[test]
fn pjrt_and_native_training_agree() {
    // same seed ⇒ identical delay/code randomness; gradients differ only by
    // backend numerics, so the NMSE trajectories must track closely.
    let dir = require_artifacts!();
    let mut cfg = ExperimentConfig::small();
    cfg.max_epochs = 300;
    cfg.target_nmse = 0.0;
    let mut native_sim = SimCoordinator::new(&cfg).unwrap();
    cfg.artifacts_dir = Some(dir);
    let mut pjrt_sim = SimCoordinator::new(&cfg).unwrap();
    let rn = native_sim.train_cfl().unwrap();
    let rp = pjrt_sim.train_cfl().unwrap();
    assert_eq!(rn.trace.points.len(), rp.trace.points.len());
    let (a, b) = (rn.trace.final_nmse().unwrap(), rp.trace.final_nmse().unwrap());
    assert!(
        ((a / b).log10()).abs() < 0.05,
        "backends diverged: native {a:.4e} vs pjrt {b:.4e}"
    );
}

#[test]
fn pjrt_chunked_tall_gradients_match_native() {
    // inputs taller than every artifact must be row-chunked exactly
    let dir = require_artifacts!();
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut native = NativeBackend;
    let mut rng = Rng::new(5);
    let x = Mat::randn(1300, 500, &mut rng); // > grad_dev's 512 rows
    let beta = Mat::randn(500, 1, &mut rng);
    let y = Mat::randn(1300, 1, &mut rng);
    let got = pjrt.partial_grad(&x, &beta, &y).unwrap();
    let want = native.partial_grad(&x, &beta, &y).unwrap();
    assert!(rel_err(&got, &want) < 1e-4, "chunked grad mismatch");

    let xt = Mat::randn(3000, 500, &mut rng); // > grad_srv's 2048 rows
    let yt = Mat::randn(3000, 1, &mut rng);
    let got = pjrt.parity_grad(&xt, &beta, &yt, 3000).unwrap();
    let want = native.parity_grad(&xt, &beta, &yt, 3000).unwrap();
    assert!(rel_err(&got, &want) < 1e-4, "chunked parity grad mismatch");
}
