//! Fig. 5 — Coding gain (top) and communication load (bottom) vs δ.
//!
//! Paper: at ν = (0.4, 0.4) with target NMSE 1.8·10⁻⁴, the gain peaks
//! (≈2.5×) at δ = 0.16 while the parity transfer costs ≈1.8× more bits;
//! gain is unimodal in δ (too little parity → straggler-bound, too much →
//! setup-bound) while communication load grows monotonically.
//!
//! Communication load = (parity bits + per-epoch bits × epochs-to-target)
//! / (uncoded per-epoch bits × uncoded epochs-to-target).
//!
//! Runs on the `cfl::sweep` engine: the uncoded baseline is trained once
//! (it does not depend on δ), then one CFL scenario per δ executes across
//! all cores — matching the paper's single-baseline methodology without
//! retraining the denominator six times.
//!
//! Writes `results/fig5_gain_vs_load.csv`.

mod common;

use cfl::config::ExperimentConfig;
use cfl::coordinator::SimCoordinator;
use cfl::metrics::{CsvWriter, Table};
use cfl::sweep::{run_grid, ScenarioGrid, SweepOptions};

fn main() {
    common::banner("Fig. 5", "coding gain and comm load vs δ, ν=(0.4,0.4), target 1.8e-4");
    let mut cfg = ExperimentConfig::paper();
    cfg.nu_comp = 0.4;
    cfg.nu_link = 0.4;
    cfg.target_nmse = 1.8e-4;
    cfg.max_epochs = if common::quick_mode() { 1_500 } else { 4_000 };
    let deltas = [0.04, 0.08, 0.13, 0.16, 0.22, 0.28];

    let mut baseline_sim = SimCoordinator::new(&cfg).expect("coordinator");
    let (uncoded, _) = common::timed(|| baseline_sim.train_uncoded().expect("uncoded"));
    let (tu, eu) = match (uncoded.time_to(cfg.target_nmse), uncoded.converged) {
        (Some(t), Some((e, _))) => (t, e),
        _ => panic!("uncoded baseline did not reach the target NMSE"),
    };
    let uncoded_bits = uncoded.per_epoch_bits * eu as f64;
    println!("uncoded: {eu} epochs, {tu:.0}s, {:.2} Gbit total\n", uncoded_bits / 1e9);

    let grid = ScenarioGrid::new(&cfg).axis_f64("delta", &deltas).expect("delta axis");
    let opts = SweepOptions { uncoded_baseline: false, progress: true, ..Default::default() };
    let (outcomes, secs) = common::timed(|| run_grid(&grid, &opts).expect("sweep"));

    let dir = common::results_dir();
    let mut csv = CsvWriter::create(
        format!("{dir}/fig5_gain_vs_load.csv"),
        &["delta", "gain", "comm_load", "t_cfl_s", "epochs", "setup_s"],
    )
    .unwrap();
    let mut table = Table::new(&["δ", "gain", "comm load", "t_CFL (s)", "epochs", "setup (s)"]);

    let mut series = Vec::new();
    for (o, &delta) in outcomes.iter().zip(&deltas) {
        let t_cfl = o.coded.time_to(cfg.target_nmse);
        // gain and comm load against the shared baseline
        let (gain, load) = match (t_cfl, o.coded.converged) {
            (Some(tc), Some((ec, _))) => {
                let coded_bits =
                    o.coded.parity_upload_bits + o.coded.per_epoch_bits * ec as f64;
                (tu / tc, coded_bits / uncoded_bits)
            }
            _ => (f64::NAN, f64::NAN),
        };
        csv.write_row(&[
            delta,
            gain,
            load,
            t_cfl.unwrap_or(f64::NAN),
            o.coded.epoch_times.len() as f64,
            o.coded.setup_secs,
        ])
        .unwrap();
        table.row(&[
            format!("{delta:.2}"),
            format!("{gain:.2}"),
            format!("{load:.2}"),
            t_cfl.map(|t| format!("{t:.0}")).unwrap_or_else(|| "—".into()),
            format!("{}", o.coded.epoch_times.len()),
            format!("{:.0}", o.coded.setup_secs),
        ]);
        series.push((delta, gain, load));
    }
    csv.flush().unwrap();
    println!("{}", table.render());

    // shape checks
    let best = series.iter().cloned().fold((0.0, 0.0, 0.0), |acc, s| if s.1 > acc.1 { s } else { acc });
    let gains_exceed_one = series.iter().any(|s| s.1 > 1.0);
    let load_monotone = series.windows(2).all(|w| w[1].2 >= w[0].2 - 1e-9);
    let interior_peak = best.0 > series[0].0;
    println!("shape checks (paper: gain peaks ≈2.5× at δ=0.16 with ≈1.8× comm load):");
    println!("  best gain {:.2}× at δ={:.2} (comm {:.2}×)", best.1, best.0, best.2);
    println!("  some δ beats uncoded:        {}", if gains_exceed_one { "PASS" } else { "FAIL" });
    println!("  comm load monotone in δ:     {}", if load_monotone { "PASS" } else { "FAIL" });
    println!("  gain peak at interior δ:     {}", if interior_peak { "PASS" } else { "FAIL" });
    println!("({secs:.1}s; CSV → {dir}/fig5_gain_vs_load.csv)");
    assert!(gains_exceed_one && load_monotone, "Fig. 5 shape check failed");
}
