//! Micro-benchmarks of the hot paths (§Perf): the gradient kernels
//! (native and PJRT), parity encode, the optimizer, and one full epoch.
//!
//! Run: `cargo bench --bench micro_hotpath` (add `-- --quick` for a short
//! pass). Results feed EXPERIMENTS.md §Perf.

mod common;

use cfl::config::ExperimentConfig;
use cfl::coordinator::SimCoordinator;
use cfl::fl::{GradBackend, NativeBackend};
use cfl::lb;
use cfl::linalg::Mat;
use cfl::rng::Rng;
use cfl::simnet::Fleet;

fn main() {
    common::banner("micro", "hot-path kernels and epoch step");
    let n = if common::quick_mode() { 5 } else { 20 };
    let mut rng = Rng::new(1);

    // --- L3-native gradient kernels (paper shapes) -----------------------
    let x = Mat::randn(7200, 500, &mut rng);
    let beta = Mat::randn(500, 1, &mut rng);
    let y = Mat::randn(7200, 1, &mut rng);
    let mut native = NativeBackend;
    println!("\nnative kernels:");
    let mut sink = 0.0f32;
    common::bench_n("partial_grad 7200x500 (uncoded epoch)", n, || {
        sink += native.partial_grad(&x, &beta, &y).unwrap()[(0, 0)];
    });
    let x_dev = Mat::randn(300, 500, &mut rng);
    let y_dev = Mat::randn(300, 1, &mut rng);
    common::bench_n("partial_grad 300x500 (device shard)", n, || {
        sink += native.partial_grad(&x_dev, &beta, &y_dev).unwrap()[(0, 0)];
    });
    let xt = Mat::randn(936, 500, &mut rng);
    let yt = Mat::randn(936, 1, &mut rng);
    common::bench_n("parity_grad 936x500 (master, δ=0.13)", n, || {
        sink += native.parity_grad(&xt, &beta, &yt, 936).unwrap()[(0, 0)];
    });
    let g = Mat::randn(936, 300, &mut rng);
    let w: Vec<f32> = (0..300).map(|i| 0.5 + (i % 7) as f32 * 0.05).collect();
    common::bench_n("encode 936x300x500 (device setup)", n, || {
        sink += native.encode(&g, &w, &x_dev, &y_dev).unwrap().0[(0, 0)];
    });

    // --- PJRT kernels (when artifacts are built) -------------------------
    let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art.join("manifest.txt").exists() {
        let mut pjrt = cfl::runtime::PjrtBackend::load(art.to_str().unwrap()).unwrap();
        println!("\npjrt kernels (AOT artifacts, includes pad/copy):");
        // warm the executable cache out of band
        pjrt.partial_grad(&x_dev, &beta, &y_dev).unwrap();
        common::bench_n("partial_grad 300x500 → grad_dev", n, || {
            sink += pjrt.partial_grad(&x_dev, &beta, &y_dev).unwrap()[(0, 0)];
        });
        pjrt.parity_grad(&xt, &beta, &yt, 936).unwrap();
        common::bench_n("parity_grad 936x500 → grad_srv", n, || {
            sink += pjrt.parity_grad(&xt, &beta, &yt, 936).unwrap()[(0, 0)];
        });
        pjrt.encode(&g, &w, &x_dev, &y_dev).unwrap();
        common::bench_n("encode 936x300x500 → encode_dev", n, || {
            sink += pjrt.encode(&g, &w, &x_dev, &y_dev).unwrap().0[(0, 0)];
        });
        // §Perf fast path: device-resident operands, β-only upload per call
        let h = pjrt.register_shard(&x_dev, &y_dev).unwrap().expect("registered");
        common::bench_n("partial_grad 300x500 registered", n, || {
            sink += pjrt.partial_grad_registered(h, &beta).unwrap()[(0, 0)];
        });
        let hp = pjrt.register_parity(&xt, &yt, 936).unwrap().expect("registered parity");
        common::bench_n("parity_grad 936x500 registered", n, || {
            sink += pjrt.parity_grad_registered(hp, &beta).unwrap()[(0, 0)];
        });
    } else {
        println!("\n(pjrt kernels skipped: run `make artifacts`)");
    }

    // --- optimizer and epoch step ----------------------------------------
    println!("\ncoordination:");
    let cfg = ExperimentConfig::paper();
    let fleet = Fleet::from_config(&cfg, &mut Rng::new(2));
    common::bench_n("optimizer Eqs.13-16 (24 devices)", n, || {
        sink += lb::optimize(&fleet, 2160, 1.0).unwrap().epoch_deadline as f32;
    });

    let mut cfg_epoch = ExperimentConfig::paper();
    cfg_epoch.max_epochs = 25;
    cfg_epoch.target_nmse = 0.0;
    let mut sim = SimCoordinator::new(&cfg_epoch).unwrap();
    common::bench_n("25 CFL epochs, paper scale (native)", 3.min(n), || {
        sink += sim.train_cfl().unwrap().trace.final_nmse().unwrap() as f32;
    });

    std::hint::black_box(sink);
    println!("\ndone.");
}
