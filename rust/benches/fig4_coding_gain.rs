//! Fig. 4 — Coding gain across heterogeneity levels.
//!
//! Paper: the ratio of uncoded to CFL convergence time (to NMSE ≤ 3·10⁻⁴)
//! over the grid (ν_comp, ν_link) ∈ {0, 0.1, 0.2}²: ≈ 1 at (0,0) and up
//! to "nearly 4×" at (0.2, 0.2), monotone-ish in both axes. CFL here uses
//! the optimizer's own δ (Eqs. 14–16), as in the paper.
//!
//! Runs on the `cfl::sweep` engine: the 3×3 grid executes across all
//! cores instead of one scenario at a time (scenario results are
//! identical to a serial run by construction).
//!
//! Writes `results/fig4_coding_gain.csv`.

mod common;

use cfl::config::ExperimentConfig;
use cfl::metrics::{CsvWriter, Table};
use cfl::sweep::{run_grid, ScenarioGrid, SweepOptions};

fn main() {
    common::banner("Fig. 4", "coding gain vs heterogeneity (target NMSE 3e-4)");
    let grid_values = [0.0, 0.1, 0.2];
    let quick = common::quick_mode();

    let mut cfg = ExperimentConfig::paper();
    cfg.max_epochs = if quick { 1_200 } else { 3_000 };
    let grid = ScenarioGrid::new(&cfg)
        .axis_f64("nu_comp", &grid_values)
        .expect("nu_comp axis")
        .axis_f64("nu_link", &grid_values)
        .expect("nu_link axis");
    let opts = SweepOptions { progress: true, ..Default::default() };
    let (outcomes, secs) = common::timed(|| run_grid(&grid, &opts).expect("sweep"));

    let dir = common::results_dir();
    let mut csv = CsvWriter::create(
        format!("{dir}/fig4_coding_gain.csv"),
        &["nu_comp", "nu_link", "delta_opt", "t_cfl_s", "t_uncoded_s", "gain"],
    )
    .unwrap();

    let mut table = Table::new(&["ν_comp", "ν_link", "δ*", "t_CFL (s)", "t_unc (s)", "gain"]);
    let mut gains = std::collections::BTreeMap::new();
    for o in &outcomes {
        let (nu_comp, nu_link) = (o.scenario.cfg.nu_comp, o.scenario.cfg.nu_link);
        let target = o.scenario.cfg.target_nmse;
        let tc = o.coded.time_to(target).unwrap_or(f64::NAN);
        let tu = o
            .uncoded
            .as_ref()
            .and_then(|u| u.time_to(target))
            .unwrap_or(f64::NAN);
        let gain = tu / tc;
        gains.insert(((nu_comp * 10.0) as u32, (nu_link * 10.0) as u32), gain);
        csv.write_row(&[nu_comp, nu_link, o.coded.delta, tc, tu, gain]).unwrap();
        table.row(&[
            format!("{nu_comp:.1}"),
            format!("{nu_link:.1}"),
            format!("{:.3}", o.coded.delta),
            format!("{tc:.0}"),
            format!("{tu:.0}"),
            format!("{gain:.2}"),
        ]);
    }
    csv.flush().unwrap();
    println!("{}", table.render());

    let g00 = gains[&(0, 0)];
    let g11 = gains[&(1, 1)];
    let g22 = gains[&(2, 2)];
    let min_gain = gains.values().cloned().fold(f64::INFINITY, f64::min);
    println!("shape checks (paper: ≈1 at (0,0), growing with heterogeneity — 'nearly 4' at (0.2,0.2)):");
    let homogeneous_near_one = g00 < 1.6;
    let homogeneous_is_min = (g00 - min_gain).abs() < 1e-9;
    let diagonal_grows = g00 < g11 && g11 < g22 && g22 > 1.5;
    println!("  gain(0,0) ≈ 1 (got {g00:.2}):            {}", if homogeneous_near_one { "PASS" } else { "FAIL" });
    println!("  gain(0,0) is the grid minimum:           {}", if homogeneous_is_min { "PASS" } else { "FAIL" });
    println!("  diagonal grows {g00:.2} → {g11:.2} → {g22:.2}:   {}", if diagonal_grows { "PASS" } else { "FAIL" });
    println!("({secs:.1}s; CSV → {dir}/fig4_coding_gain.csv)");
    assert!(
        homogeneous_near_one && homogeneous_is_min && diagonal_grows,
        "Fig. 4 shape check failed"
    );
}
