//! Fig. 3 — Histograms of the per-epoch gather time.
//!
//! Top: time to receive all m partial gradients in uncoded FL (heavy tail
//! "extending beyond 150 s"). Bottom: time until the devices had returned
//! m − c partial gradients in CFL (δ = 0.13) — the tail is clipped because
//! the last c data-points' worth of gradients come from the master's
//! parity computation instead of the stragglers.
//!
//! Runs as a single-cell grid on the `cfl::sweep` engine (the axis-free
//! grid is the base scenario; the runner trains CFL and the uncoded
//! baseline) — the delay statistics come straight out of the unified
//! `RunResult`.
//!
//! Writes `results/fig3_{uncoded,cfl}.csv`.

mod common;

use cfl::config::ExperimentConfig;
use cfl::metrics::CsvWriter;
use cfl::stats::{quantile, Histogram};
use cfl::sweep::{run_grid, ScenarioGrid, SweepOptions};

fn main() {
    common::banner("Fig. 3", "epoch gather-time histograms: uncoded (m) vs CFL (m−c)");
    let mut cfg = ExperimentConfig::paper();
    cfg.max_epochs = if common::quick_mode() { 400 } else { 2_000 };
    cfg.target_nmse = 0.0; // fixed epoch count: we want delay statistics
    cfg.delta = Some(0.13);

    // an axis-free grid expands to exactly the base scenario
    let grid = ScenarioGrid::new(&cfg);
    let opts = SweepOptions { progress: true, ..Default::default() };
    let (outcomes, secs) = common::timed(|| run_grid(&grid, &opts).expect("fig3 scenario"));
    let coded = &outcomes[0].coded;
    let uncoded = outcomes[0].uncoded.as_ref().expect("uncoded baseline");

    let mut h_unc = Histogram::new(0.0, 160.0, 32);
    h_unc.extend(&uncoded.epoch_times);
    let finite_mc: Vec<f64> =
        coded.gather_mc_times.iter().copied().filter(|t| t.is_finite()).collect();
    let mut h_cfl = Histogram::new(0.0, 160.0, 32);
    h_cfl.extend(&finite_mc);

    println!("\nuncoded: time to receive m partial gradients ({} epochs)", uncoded.epoch_times.len());
    println!("{}", h_unc.render(48));
    println!("CFL δ=0.13: time to receive m−c partial gradients ({} epochs, {} never reached m−c)",
        coded.gather_mc_times.len(), coded.gather_mc_times.len() - finite_mc.len());
    println!("{}", h_cfl.render(48));

    let dir = common::results_dir();
    for (name, h) in [("uncoded", &h_unc), ("cfl", &h_cfl)] {
        let mut csv =
            CsvWriter::create(format!("{dir}/fig3_{name}.csv"), &["bin_center_s", "count"]).unwrap();
        for (center, count) in h.series() {
            csv.write_row(&[center, count as f64]).unwrap();
        }
        csv.flush().unwrap();
    }

    // shape checks: uncoded must have the heavy tail, CFL must clip it
    let unc_p99 = quantile(&uncoded.epoch_times, 0.99);
    let cfl_p99 = quantile(&finite_mc, 0.99);
    let unc_tail = h_unc.tail_fraction(100.0);
    let cfl_tail = h_cfl.tail_fraction(100.0);
    println!("uncoded: mean {:.1}s  p99 {:.1}s  P{{>100s}} = {:.3}", {
        let s: f64 = uncoded.epoch_times.iter().sum();
        s / uncoded.epoch_times.len() as f64
    }, unc_p99, unc_tail);
    println!("CFL:     mean {:.1}s  p99 {:.1}s  P{{>100s}} = {:.3}", {
        let s: f64 = finite_mc.iter().sum();
        s / finite_mc.len() as f64
    }, cfl_p99, cfl_tail);
    println!("\nshape checks (paper: uncoded gather heavy-tailed, CFL tail clipped):");
    // the paper's literal ">150 s" extremes need the rare multi-retransmission
    // draws of very long runs; the structural claim is the upper tail itself
    let unc_med = quantile(&uncoded.epoch_times, 0.5);
    let unc_max = uncoded.epoch_times.iter().copied().fold(0.0f64, f64::max);
    let cfl_max = finite_mc.iter().copied().fold(0.0f64, f64::max);
    let heavy_tail = unc_max > 1.6 * unc_med;
    let clipped = cfl_p99 < unc_p99 && cfl_max < unc_max;
    println!(
        "  uncoded max {unc_max:.0}s > 1.6×median {unc_med:.0}s: {}",
        if heavy_tail { "PASS" } else { "FAIL" }
    );
    println!("  CFL p99/max below uncoded:    {}", if clipped { "PASS" } else { "FAIL" });
    println!("({secs:.1}s; CSVs → {dir}/fig3_*.csv)");
    assert!(heavy_tail && clipped, "Fig. 3 shape check failed");
}
