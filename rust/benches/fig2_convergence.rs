//! Fig. 2 — Convergence (NMSE vs training time) of CFL for different
//! coding-redundancy values, against uncoded FL and the LS bound.
//!
//! Paper setup: ν = (0.2, 0.2), δ ∈ {0 (uncoded), 0.065, 0.13, 0.16,
//! 0.28}; coded curves start late (parity upload) but clip the straggler
//! tail and overtake at low NMSE; at NMSE 0.1 uncoded wins, at 10⁻³ a
//! coded curve wins.
//!
//! Runs on the `cfl::sweep` engine: the uncoded baseline is trained once
//! (it does not depend on δ), then one CFL scenario per δ executes across
//! all cores via a `delta` grid axis.
//!
//! Writes one CSV per curve under `results/fig2/`.

mod common;

use cfl::config::ExperimentConfig;
use cfl::coordinator::SimCoordinator;
use cfl::metrics::Table;
use cfl::sweep::{run_grid, ScenarioGrid, SweepOptions};

fn main() {
    common::banner("Fig. 2", "NMSE vs training time for δ sweeps, ν=(0.2,0.2)");
    let mut cfg = ExperimentConfig::paper();
    cfg.max_epochs = if common::quick_mode() { 900 } else { 3_000 };
    cfg.target_nmse = 2e-4; // run past 3e-4 so the curves cross the floor region
    let deltas = [0.065, 0.13, 0.16, 0.28];

    let dir = common::results_dir();
    std::fs::create_dir_all(format!("{dir}/fig2")).unwrap();
    let mut baseline = SimCoordinator::new(&cfg).expect("coordinator");
    let ls = baseline.ls_bound().expect("ls bound");

    let ((uncoded, outcomes), secs) = common::timed(|| {
        let uncoded = baseline.train_uncoded().expect("uncoded run");
        let grid = ScenarioGrid::new(&cfg).axis_f64("delta", &deltas).expect("delta axis");
        let opts =
            SweepOptions { uncoded_baseline: false, progress: true, ..Default::default() };
        let outcomes = run_grid(&grid, &opts).expect("delta sweep");
        (uncoded, outcomes)
    });
    uncoded.write_trace_csv(&format!("{dir}/fig2/uncoded.csv")).unwrap();
    let mut runs = Vec::new();
    for (o, &delta) in outcomes.iter().zip(&deltas) {
        o.coded.write_trace_csv(&format!("{dir}/fig2/cfl_delta{delta}.csv")).unwrap();
        runs.push(o.coded.clone());
    }

    // paper-style summary: time to reach several NMSE levels per curve
    let levels = [1e-1, 1e-2, 1e-3, 3e-4];
    let mut table = Table::new(&[
        "curve", "setup (s)", "t*(s)", "t→1e-1", "t→1e-2", "t→1e-3", "t→3e-4", "final NMSE",
    ]);
    for run in std::iter::once(&uncoded).chain(runs.iter()) {
        let mut cells = vec![
            run.label.clone(),
            format!("{:.0}", run.setup_secs),
            if run.epoch_deadline.is_finite() {
                format!("{:.1}", run.epoch_deadline)
            } else {
                "inf".into()
            },
        ];
        for &lv in &levels {
            cells.push(
                run.trace.time_to_nmse(lv).map(|t| format!("{t:.0}")).unwrap_or("—".into()),
            );
        }
        cells.push(format!("{:.2e}", run.trace.final_nmse().unwrap()));
        table.row(&cells);
    }
    println!("{}", table.render());
    println!("LS bound NMSE: {ls:.3e}");

    // Shape checks against the paper's narrative. Note on the paper's
    // "uncoded outperforms at NMSE 0.1" crossing: it requires the parity
    // upload to cost thousands of seconds, which the paper's figure
    // magnitudes elsewhere contradict (see DESIGN.md §Substitutions) —
    // with base-rate setup accounting the offsets are real but small, so
    // the robust, checkable structure is (a) coded pays an upfront offset
    // ordered by δ, (b) the advantage of coding *grows* as the NMSE target
    // tightens (coding pays off late), (c) a coded curve wins at 1e-3.
    let t_u_fine = uncoded.trace.time_to_nmse(1e-3);
    let fine_winner_is_coded = runs
        .iter()
        .filter_map(|r| r.trace.time_to_nmse(1e-3))
        .any(|t| t_u_fine.map(|tu| t < tu).unwrap_or(true));
    let offsets_ordered = runs.windows(2).all(|w| w[0].setup_secs <= w[1].setup_secs)
        && runs.iter().all(|r| r.setup_secs > 0.0);
    // larger δ ⇒ shorter deadline ⇒ faster convergence at fine targets
    // (with base-rate setup the offsets never dominate, so the ordering is
    // monotone in δ; under per-packet accounting large δ loses instead —
    // see the `ablation` bench)
    let t3: Vec<f64> = runs.iter().filter_map(|r| r.trace.time_to_nmse(1e-3)).collect();
    let delta_ordering = t3.len() == runs.len() && t3.windows(2).all(|w| w[1] <= w[0] + 1e-9);
    println!("\nshape checks (coding pays upfront, wins late; offsets ordered by δ):");
    println!("  t→1e-3 monotone ↓ in δ:        {}", if delta_ordering { "PASS" } else { "FAIL" });
    println!("  a coded curve fastest to 1e-3: {}", if fine_winner_is_coded { "PASS" } else { "FAIL" });
    println!("  setup offsets ordered by δ:    {}", if offsets_ordered { "PASS" } else { "FAIL" });
    println!("({secs:.1}s; CSVs → {dir}/fig2/)");
    assert!(delta_ordering && fine_winner_is_coded && offsets_ordered);
}
