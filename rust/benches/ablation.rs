//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Setup-cost accounting** (the paper's under-specified model): how
//!    the coding gain at each δ changes under base-rate vs adapted-rate
//!    vs per-packet parity-upload accounting. Under the pessimistic
//!    models, large δ stops paying (interior/edge optima move) and the
//!    paper's "uncoded wins early" crossing re-appears.
//! 2. **Generator distribution** (§III-A offers both): Gaussian vs
//!    Bernoulli(½)/Rademacher codes — convergence must be statistically
//!    indistinguishable (both satisfy GᵀG/c → I).
//! 3. **Weighting (Eq. 17) on/off**: dropping the weight matrix biases
//!    the combined gradient; measured as the NMSE floor it converges to.
//!
//! Parts 1 and 2 run as `cfl::sweep` grids (`setup_cost × delta` and
//! `generator` axes) across all cores; part 3 needs an off-policy weight
//! override and stays a pair of direct coordinator calls.
//!
//! Run: `cargo bench --bench ablation` (reduced sweep with `-- --quick`).

mod common;

use cfl::config::{ExperimentConfig, SetupCostKind};
use cfl::coordinator::SimCoordinator;
use cfl::metrics::Table;
use cfl::sweep::{run_grid, ScenarioGrid, SweepOptions};

fn main() {
    common::banner("ablation", "setup-cost models, generator kinds, Eq. 17 weighting");
    let quick = common::quick_mode();

    // --- 1. setup-cost accounting ----------------------------------------
    println!("\n[1] setup-cost accounting vs coding gain (ν = (0.2, 0.2), target 3e-4)");
    let deltas: &[f64] = if quick { &[0.065, 0.28] } else { &[0.065, 0.13, 0.28] };
    let mut cfg = ExperimentConfig::paper();
    cfg.max_epochs = if quick { 900 } else { 2_000 };

    // the uncoded baseline has no setup phase, so it is independent of
    // both axes — train it once and share the denominator
    let mut baseline = SimCoordinator::new(&cfg).expect("coordinator");
    let uncoded = baseline.train_uncoded().expect("uncoded");
    let tu = uncoded.time_to(cfg.target_nmse).expect("uncoded converged");

    let grid = ScenarioGrid::new(&cfg)
        .axis("setup_cost", ["base-rate", "adapted-rate", "per-packet"])
        .expect("setup_cost axis")
        .axis_f64("delta", deltas)
        .expect("delta axis");
    let opts = SweepOptions { uncoded_baseline: false, progress: true, ..Default::default() };
    let outcomes = run_grid(&grid, &opts).expect("setup-cost sweep");

    let mut table = Table::new(&["setup model", "δ", "setup (s)", "t→target (s)", "gain"]);
    let mut base_small_delta_gain = 0.0;
    let mut perpkt_small_delta_gain = 0.0;
    let mut perpkt_large_delta_gain = f64::NAN;
    for o in &outcomes {
        let kind = o.scenario.cfg.setup_cost;
        let delta = o.coded.delta;
        let (t, gain) = match o.coded.time_to(cfg.target_nmse) {
            Some(t) => (t, tu / t),
            None => (f64::NAN, f64::NAN),
        };
        table.row(&[
            format!("{kind:?}"),
            format!("{delta:.3}"),
            format!("{:.0}", o.coded.setup_secs),
            format!("{t:.0}"),
            format!("{gain:.2}"),
        ]);
        match (kind, delta) {
            (SetupCostKind::BaseRate, d) if d < 0.1 => base_small_delta_gain = gain,
            (SetupCostKind::PerPacket, d) if d < 0.1 => perpkt_small_delta_gain = gain,
            (SetupCostKind::PerPacket, d) if d > 0.2 => perpkt_large_delta_gain = gain,
            _ => {}
        }
    }
    println!("{}", table.render());
    let ordering_flips = perpkt_large_delta_gain < perpkt_small_delta_gain;
    println!(
        "  per-packet accounting punishes large δ (gain {:.2} < {:.2}): {}",
        perpkt_large_delta_gain,
        perpkt_small_delta_gain,
        if ordering_flips { "PASS" } else { "FAIL" }
    );
    let _ = base_small_delta_gain;

    // --- 2. generator distribution ---------------------------------------
    println!("\n[2] Gaussian vs Bernoulli(1/2) generator (δ = 0.13, small scale)");
    let mut cfg = ExperimentConfig::small();
    cfg.delta = Some(0.13);
    cfg.max_epochs = 2_500;
    cfg.target_nmse = 0.0;
    let grid = ScenarioGrid::new(&cfg)
        .axis("generator", ["gaussian", "bernoulli"])
        .expect("generator axis");
    let opts = SweepOptions { uncoded_baseline: false, progress: false, ..Default::default() };
    let gen_outcomes = run_grid(&grid, &opts).expect("generator sweep");

    let mut table = Table::new(&["generator", "epochs", "final NMSE"]);
    let mut finals = Vec::new();
    for o in &gen_outcomes {
        let f = o.coded.trace.final_nmse().unwrap();
        finals.push(f);
        table.row(&[
            format!("{:?}", o.scenario.cfg.generator),
            format!("{}", o.coded.epoch_times.len()),
            format!("{f:.3e}"),
        ]);
    }
    println!("{}", table.render());
    let same_decade = (finals[0].log10() - finals[1].log10()).abs() < 0.5;
    println!("  codes statistically equivalent: {}", if same_decade { "PASS" } else { "FAIL" });

    // --- 3. Eq. 17 weighting on/off --------------------------------------
    // "off" is emulated by δ large + weights forced to 1 via a miss-prob
    // of 0 — the parity gradient then double-counts the on-time devices.
    // This needs an off-policy weight override, which no config axis
    // expresses — two direct runs, not a scenario loop.
    println!("\n[3] Eq. 17 weighting (unbiasedness ablation, small scale)");
    let mut cfg = ExperimentConfig::small();
    cfg.delta = Some(0.2);
    cfg.max_epochs = 2_500;
    cfg.target_nmse = 0.0;
    let mut sim = SimCoordinator::new(&cfg).expect("coordinator");
    let weighted = sim.train_cfl().expect("weighted");
    let unweighted = {
        let mut policy = sim.policy().expect("policy");
        for p in policy.miss_probs.iter_mut() {
            *p = 1.0; // w_ik = 1 everywhere → parity counts every point fully
        }
        sim.train_cfl_with_policy(&policy).expect("unweighted")
    };
    let (fw, fu) = (
        weighted.trace.final_nmse().unwrap(),
        unweighted.trace.final_nmse().unwrap(),
    );
    println!("  weighted   final NMSE: {fw:.3e}");
    println!("  unweighted final NMSE: {fu:.3e} (double-counts on-time devices)");
    // the unweighted combiner over-counts on-time devices by up to (1+Pᵢ);
    // at small scale that shows up as a ~1.2–1.5× worse stationary floor
    let bias_visible = fu > fw * 1.2;
    println!("  weighting improves the floor: {}", if bias_visible { "PASS" } else { "FAIL" });

    assert!(ordering_flips && same_decade && bias_visible, "ablation checks failed");
    println!("\ndone.");
}
