//! Shared bench-harness helpers (criterion is unavailable offline; every
//! figure bench is a `harness = false` binary using these utilities).

use std::time::Instant;

/// Wall-clock a closure, returning (result, seconds).
#[allow(dead_code)]
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Repeat a closure `n` times, reporting min/mean/max seconds — the
/// micro-bench primitive for §Perf.
#[allow(dead_code)]
pub fn bench_n(label: &str, n: usize, mut f: impl FnMut()) -> (f64, f64, f64) {
    assert!(n > 0);
    let mut times = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let min = times.iter().copied().fold(f64::INFINITY, f64::min);
    let max = times.iter().copied().fold(0.0f64, f64::max);
    let mean = times.iter().sum::<f64>() / n as f64;
    println!("  {label:<38} min {:>9.3} ms  mean {:>9.3} ms  max {:>9.3} ms", min * 1e3, mean * 1e3, max * 1e3);
    (min, mean, max)
}

/// Standard header for figure benches.
#[allow(dead_code)]
pub fn banner(fig: &str, what: &str) {
    println!("==================================================================");
    println!("{fig}: {what}");
    println!("==================================================================");
}

/// Results directory (created on demand).
#[allow(dead_code)]
pub fn results_dir() -> String {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir).expect("mkdir results/");
    dir.to_str().unwrap().to_string()
}

/// `--quick` flag: benches run reduced sweeps under `cargo bench -- --quick`
/// (and full sweeps otherwise).
#[allow(dead_code)]
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("CFL_BENCH_QUICK").is_ok()
}
