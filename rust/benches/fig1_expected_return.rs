//! Fig. 1 — Expected value of individual return vs load assignment.
//!
//! Paper: for a representative edge device, `E[R(t; ℓ̃)]` as a function of
//! the number of raw points processed, for epoch windows t ∈ {0.7, 1.1,
//! 1.5} s. The curve rises ~linearly, peaks at an interior ℓ*, then
//! collapses to 0 once the deterministic compute time alone exceeds t.
//!
//! We evaluate both the analytic CDF-based expectation (what the optimizer
//! uses) and a Monte-Carlo estimate (validating the analytic path), print
//! the three series, and write `results/fig1_expected_return.csv`.
//!
//! The load axis runs on the sweep engine's parallel executor
//! (`cfl::sweep::run_tasks`): each load is one task with its own derived
//! seed, so output is byte-identical for any worker count — no bespoke
//! serial loop.

mod common;

use cfl::config::ExperimentConfig;
use cfl::metrics::{CsvWriter, Table};
use cfl::rng::{mix_seed, Rng};
use cfl::simnet::Fleet;
use cfl::sweep::run_tasks;

fn main() {
    common::banner("Fig. 1", "expected individual return E[R(t; l)] vs load");
    let cfg = ExperimentConfig::paper();
    let fleet = Fleet::from_config(&cfg, &mut Rng::new(cfg.seed));

    // representative device: the paper plots one "i-th device" whose
    // windows t ∈ {0.7, 1.1, 1.5} s straddle its full-load delay (that is
    // what makes the t = 0.7 s peak interior while t = 1.5 s still shows
    // growth). Pick the device whose E[T(300)] is nearest 1.3 s.
    let dev = fleet
        .devices
        .iter()
        .min_by(|a, b| {
            (a.mean_total_delay(300) - 1.3)
                .abs()
                .total_cmp(&(b.mean_total_delay(300) - 1.3).abs())
        })
        .unwrap();
    println!(
        "device: a = {:.3} ms/point, tau = {:.3} s, E[T(300)] = {:.2} s\n",
        dev.compute.secs_per_point * 1e3,
        dev.link.secs_per_packet,
        dev.mean_total_delay(300)
    );

    let windows = [0.7, 1.1, 1.5];
    let mc_rounds = if common::quick_mode() { 500 } else { 5_000 };
    // scan past the ℓᵢ = 300 shard cap: Fig. 1 illustrates the shape of
    // E[R(t; ℓ)] itself (the Eq. 14 argmax constrains to ℓ ≤ ℓᵢ separately)
    let loads: Vec<usize> = (0..=600).step_by(10).collect();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let (rows, secs) = common::timed(|| {
        run_tasks(loads, workers, |load| {
            // per-load seed ⇒ the MC series is independent of worker count
            let mut rng = Rng::new(mix_seed(7, load as u64));
            let mut cells = Vec::with_capacity(windows.len());
            for &t in &windows {
                let analytic = dev.expected_return(load, t);
                let hits = (0..mc_rounds)
                    .filter(|_| load > 0 && dev.sample_total_delay(load, &mut rng) <= t)
                    .count();
                let mc = load as f64 * hits as f64 / mc_rounds as f64;
                cells.push((analytic, mc));
            }
            Ok((load, cells))
        })
        .expect("fig1 load scan")
    });

    let dir = common::results_dir();
    let mut csv = CsvWriter::create(
        format!("{dir}/fig1_expected_return.csv"),
        &["load", "t0.7_analytic", "t0.7_mc", "t1.1_analytic", "t1.1_mc", "t1.5_analytic", "t1.5_mc"],
    )
    .unwrap();

    let mut table = Table::new(&["load", "E[R] t=0.7s", "E[R] t=1.1s", "E[R] t=1.5s"]);
    let mut peaks = vec![(0usize, 0.0f64); windows.len()];
    for (load, cells) in &rows {
        let mut row = vec![*load as f64];
        let mut tcells = vec![*load as f64];
        for (wi, &(analytic, mc)) in cells.iter().enumerate() {
            row.push(analytic);
            row.push(mc);
            tcells.push(analytic);
            if analytic > peaks[wi].1 {
                peaks[wi] = (*load, analytic);
            }
        }
        csv.write_row(&row).unwrap();
        table.row_f(&tcells, 1);
    }
    csv.flush().unwrap();
    println!("{}", table.render());

    println!("shape checks (paper: concave with interior max, larger t ⇒ larger/later peak):");
    for (w, &(l, r)) in windows.iter().zip(&peaks) {
        println!("  t = {w} s: peak E[R] = {r:.1} at load {l}");
    }
    let ok = peaks.windows(2).all(|p| p[1].1 >= p[0].1 && p[1].0 >= p[0].0)
        && peaks.iter().all(|&(l, _)| l > 0 && l < 600);
    println!("  interior peaks, ordered by window: {}", if ok { "PASS" } else { "FAIL" });
    println!("({secs:.1}s; CSV → {dir}/fig1_expected_return.csv)");
    assert!(ok, "Fig. 1 shape check failed");
}
