"""Build-time compile path for CFL: JAX model (L2) + Pallas kernels (L1).

Nothing in this package is imported at runtime — ``aot.py`` lowers the
computations to HLO text once (``make artifacts``) and the rust coordinator
loads the artifacts through PJRT.
"""
