"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Each artifact is lowered for a fixed *padded* shape; the rust runtime
zero-pads logical operands up to the artifact shape (exact for every graph
here — see model.py docstring) and slices the result. Padded shapes are
multiples of 128 to match the Pallas block size and TPU lane width.

Outputs (``<out>/``):
  <name>.hlo.txt       one per artifact
  manifest.txt         "name kind file dims..." lines the rust runtime parses
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Padded artifact sizes. Paper setup (§IV): d = 500 → D = 512; per-device
# shard ℓi = 300 → L = 512; composite parity c ≤ 0.28·7200 ≈ 2016 → C = 2048.
# The *_s variants keep tests and the quickstart example fast.
D, L, C = 512, 512, 2048
DS, LS, CS = 128, 128, 128

# CPU-artifact tile sizes (§Perf): interpret-mode Pallas lowers the grid
# to an HLO loop whose per-step dynamic-slice copies dominate on the CPU
# backend, so the AOT artifacts use the largest tiles that still fit the
# (generous) CPU cache budget — one step for the 512-row gradient, four
# for the 2048-row parity gradient. TPU builds would keep 128.
_grad_cpu = functools.partial(model.device_grad, block_rows=512)
_pgrad_cpu = functools.partial(model.server_parity_grad, block_rows=512)
_encode_cpu = functools.partial(model.encode_parity, block_c=512, block_l=512)

# name → (kind, fn, example_args, dims)
#   kind encodes the operand convention the rust runtime implements.
ARTIFACTS = {
    "grad_dev": (
        "grad", _grad_cpu,
        (spec(L, D), spec(D, 1), spec(L, 1), spec(L, 1)), (L, D)),
    "grad_dev_s": (
        "grad", model.device_grad,
        (spec(LS, DS), spec(DS, 1), spec(LS, 1), spec(LS, 1)), (LS, DS)),
    "grad_srv": (
        "pgrad", _pgrad_cpu,
        (spec(C, D), spec(D, 1), spec(C, 1), spec(1, 1)), (C, D)),
    "grad_srv_s": (
        "pgrad", model.server_parity_grad,
        (spec(CS, DS), spec(DS, 1), spec(CS, 1), spec(1, 1)), (CS, DS)),
    "encode_dev": (
        "encode", _encode_cpu,
        (spec(C, L), spec(L, 1), spec(L, D), spec(L, 1)), (C, L, D)),
    "encode_dev_s": (
        "encode", model.encode_parity,
        (spec(CS, LS), spec(LS, 1), spec(LS, DS), spec(LS, 1)), (CS, LS, DS)),
    "gd_step": (
        "gd_step", model.gd_step,
        (spec(D, 1), spec(D, 1), spec(1, 1)), (D,)),
    "nmse": (
        "nmse", model.nmse,
        (spec(D, 1), spec(D, 1)), (D,)),
}


def build(out_dir: str, only=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for name, (kind, fn, args, dims) in ARTIFACTS.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest.append(f"{name} {kind} {fname} " + " ".join(map(str, dims)))
        print(f"  {name:14s} kind={kind:8s} dims={dims}  {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("# name kind file dims...\n")
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts + manifest to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    args = ap.parse_args()
    build(args.out, only=args.only)


if __name__ == "__main__":
    main()
