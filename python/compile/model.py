"""L2 JAX model for Coded Federated Learning (build-time only).

The paper's workload is full-batch linear-regression gradient descent
(§II). The L2 graphs below are the units the rust coordinator executes via
PJRT each epoch / at setup:

* ``device_grad``   — Eq. (2) inner sum over one device's systematic shard,
  with an optional row-validity mask so one padded artifact shape serves
  every logical shard size. Calls the L1 ``partial_grad`` Pallas kernel.
* ``server_parity_grad`` — Eq. (18) numerator: the master's redundant
  gradient over the composite parity set, *normalized by the logical parity
  count c* (passed as a scalar operand so the same artifact serves any c).
* ``encode_parity`` — Eq. (9): one-time parity generation on a device.
  Calls the L1 ``encode`` Pallas kernel.
* ``gd_step``       — Eq. (3) model update (kept separate so the rust
  coordinator can combine coded/uncoded gradients per Eqs. 18–19 first).

Masking conventions (all exact, no approximation):
  - Padded X rows are zero and their y entries zero → contribute 0 to g.
  - Padded model columns are zero in X and β → g entries are 0 there.
  - Parity-row padding: G rows beyond c are zero.
The rust runtime zero-fills, so no mask operand is needed for correctness;
``device_grad`` still takes a row mask to support *puncturing* (§III-C)
without re-uploading a differently-padded shard.
"""

import jax
import jax.numpy as jnp

from .kernels import encode as _encode_kernel
from .kernels import partial_grad as _grad_kernel


def device_grad(x, beta, y, row_mask, *, block_rows=128):
    """Partial gradient over a (possibly punctured) systematic shard.

    g = Xᵀ diag(mask) (Xβ − y), computed as the Pallas kernel over the
    mask-scaled rows. mask entries are 0.0 (punctured / padding) or 1.0.

    ``block_rows`` is the L1 kernel's row-tile height — 128 targets TPU
    VMEM; the AOT path lowers CPU artifacts with larger tiles (§Perf:
    interpret-mode Pallas becomes an HLO loop whose per-step slice copies
    dominate on CPU, so fewer/larger steps win there).

    Shapes: x (L, D), beta (D, 1), y (L, 1), row_mask (L, 1) → (D, 1).
    """
    xm = x * row_mask
    ym = y * row_mask
    return _grad_kernel(xm, beta, ym, block_rows=block_rows)


def server_parity_grad(xt, beta, yt, inv_c, *, block_rows=128):
    """Normalized parity gradient (Eq. 18 LHS): (1/c)·X̃ᵀ(X̃β − ỹ).

    ``inv_c`` is the scalar 1/c (shape (1, 1)) so one artifact covers every
    redundancy level; padded parity rows are zero and drop out.

    Shapes: xt (C, D), beta (D, 1), yt (C, 1), inv_c (1, 1) → (D, 1).
    """
    g = _grad_kernel(xt, beta, yt, block_rows=block_rows)
    return g * inv_c


def encode_parity(g, w, x, y, *, block_c=128, block_l=128):
    """One-time device-side parity generation (Eq. 9).

    Shapes: g (C, L), w (L, 1), x (L, D), y (L, 1) → ((C, D), (C, 1)).
    """
    return _encode_kernel(g, w, x, y, block_c=block_c, block_l=block_l)


def gd_step(beta, grad, lr_over_m):
    """β ← β − (μ/m)·g (Eq. 3). lr_over_m shape (1, 1)."""
    return beta - lr_over_m * grad


def nmse(beta_hat, beta_star):
    """Normalized MSE ‖β̂ − β‖²/‖β‖² (§IV). Shapes (D,1),(D,1) → (1,1)."""
    diff = beta_hat - beta_star
    num = jnp.sum(diff * diff)
    den = jnp.sum(beta_star * beta_star)
    return (num / den).reshape(1, 1)
