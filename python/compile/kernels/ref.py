"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the L1 kernels are validated against (pytest +
hypothesis), and they double as documentation of the math:

* ``partial_grad``  — Eq. (2) inner sum of the paper, for one data shard:
  ``g = Xᵀ (X β − y)``.
* ``encode``        — Eq. (9): parity generation from weighted raw data,
  ``X̃ = G (w ⊙ X)``, ``ỹ = G (w ⊙ y)`` with ``w`` the diagonal of the
  weight matrix ``W``.

Shapes (all ``float32``):
  X: (L, D)   β: (D, 1)   y: (L, 1)   G: (C, L)   w: (L, 1)
"""

import jax.numpy as jnp


def partial_grad(x, beta, y):
    """g = Xᵀ(Xβ − y);  x:(L,D), beta:(D,1), y:(L,1) → (D,1)."""
    r = x @ beta - y
    return x.T @ r


def encode(g, w, x, y):
    """Parity data (X̃, ỹ) = (G(w⊙X), G(w⊙y)).

    g:(C,L), w:(L,1), x:(L,D), y:(L,1) → ((C,D), (C,1)).
    """
    xw = w * x
    yw = w * y
    return g @ xw, g @ yw


def gd_step(beta, grad, lr_over_m):
    """β ← β − (μ/m)·g — Eq. (3)."""
    return beta - lr_over_m * grad
