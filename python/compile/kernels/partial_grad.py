"""L1 Pallas kernel: fused partial gradient  g = Xᵀ(Xβ − y).

This is the per-epoch compute hot-spot of Coded Federated Learning: every
edge device evaluates it over its systematic shard each epoch, and the
master evaluates it over the composite parity data ``(X̃, ỹ)`` (Eq. 18 of
the paper — same kernel, different operands).

TPU-oriented design (see DESIGN.md §Hardware-Adaptation):

* The row dimension ``L`` is tiled into blocks of ``block_rows``; the grid
  walks row blocks and carries the output accumulator ``g`` across grid
  steps (output BlockSpec maps every step to the same (D,1) block, which is
  the canonical Pallas reduction idiom).
* Each grid step performs two MXU-shaped matmuls on an (bm, D) f32 tile:
  ``r = X_blk @ β − y_blk`` (bm×D · D×1) then ``X_blkᵀ @ r`` (D×bm · bm×1).
  The fusion keeps the residual ``r`` in VMEM — it never round-trips to HBM,
  which is the whole point versus composing two XLA GEMM calls.
* VMEM footprint per step ≈ (bm·D + D + bm + D) f32; with bm=128, D=512
  that is ~0.26 MiB, far under the ~16 MiB VMEM budget, leaving room for
  double buffering of the X stream (the only HBM-bound operand).
* Zero-padding is exact: padded rows contribute 0 to g; padded model
  columns produce g-entries of 0.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that both jax-CPU and the
rust PJRT runtime execute bit-identically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, beta_ref, y_ref, g_ref):
    """One grid step: accumulate X_blkᵀ(X_blk β − y_blk) into g."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    x = x_ref[...]
    r = jnp.dot(x, beta_ref[...], preferred_element_type=jnp.float32)
    r = r - y_ref[...]
    g_ref[...] += jnp.dot(x.T, r, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def partial_grad(x, beta, y, *, block_rows=128):
    """g = Xᵀ(Xβ − y) via a row-tiled Pallas reduction.

    Args:
      x:    (L, D) float32, L divisible by ``block_rows``.
      beta: (D, 1) float32.
      y:    (L, 1) float32.
      block_rows: row-tile height (multiple of 8; 128 targets the MXU).

    Returns:
      (D, 1) float32 gradient.
    """
    l, d = x.shape
    block_rows = min(block_rows, l)  # small shards: single row-block
    if l % block_rows != 0:
        raise ValueError(f"L={l} not divisible by block_rows={block_rows}")
    grid = (l // block_rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),  # X row stream
            pl.BlockSpec((d, 1), lambda i: (0, 0)),           # β resident
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),  # y row stream
        ],
        out_specs=pl.BlockSpec((d, 1), lambda i: (0, 0)),     # g accumulator
        out_shape=jax.ShapeDtypeStruct((d, 1), jnp.float32),
        interpret=True,
    )(x, beta, y)
