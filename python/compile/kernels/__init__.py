"""L1 Pallas kernels for Coded Federated Learning (build-time only)."""

from .encode import encode
from .partial_grad import partial_grad

__all__ = ["encode", "partial_grad"]
