"""L1 Pallas kernel: parity encoding  (X̃, ỹ) = (G(w⊙X), G(w⊙y)).

Eq. (9) of the paper: each device applies its private random generator
matrix ``G (c×ℓ)`` to its weight-scaled raw data once, before training
starts. This is the setup-phase hot-spot (c can be comparable to ℓ·n·δ),
and it runs on the *device*, so a tight kernel matters for device energy.

TPU-oriented design:

* 2-D grid over (parity-row blocks, raw-row blocks). The contraction
  dimension is the raw-row dimension L, so the second grid axis is a
  reduction axis: X̃/ỹ output blocks map only to the first axis and are
  accumulated across the second (standard Pallas matmul reduction idiom;
  the reduction axis must iterate innermost, which Pallas guarantees for
  the trailing grid dimension).
* The weighting ``w`` is fused into the G tile (``G_blk * w_blkᵀ``) so the
  weighted data ``w⊙X`` never materializes in HBM — on a real device this
  halves the HBM traffic of a two-pass (scale, then GEMM) implementation.
* Both X̃ and ỹ are produced by the same pass over G·w, sharing the fetch.
* VMEM per step ≈ (bc·bl + bl·D + bc·D) f32; bc=bl=128, D=512 → ~0.6 MiB.
* Zero padding is exact in all three dims (padded raw rows have w=0 slots
  multiplied by zero X anyway; padded parity rows are garbage-free zeros
  because G padding is zero).

``interpret=True`` — see partial_grad.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, w_ref, x_ref, y_ref, xt_ref, yt_ref):
    """One grid step: accumulate (G_blk·diag(w_blk)) @ [X_blk | y_blk]."""
    lstep = pl.program_id(1)

    @pl.when(lstep == 0)
    def _init():
        xt_ref[...] = jnp.zeros_like(xt_ref)
        yt_ref[...] = jnp.zeros_like(yt_ref)

    gw = g_ref[...] * w_ref[...].T  # (bc, bl) ⊙ broadcast (1, bl)
    xt_ref[...] += jnp.dot(gw, x_ref[...], preferred_element_type=jnp.float32)
    yt_ref[...] += jnp.dot(gw, y_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_c", "block_l"))
def encode(g, w, x, y, *, block_c=128, block_l=128):
    """Parity encode (X̃, ỹ) = (G(w⊙X), G(w⊙y)) via a 2-D tiled Pallas GEMM.

    Args:
      g: (C, L) float32 generator matrix, C % block_c == 0, L % block_l == 0.
      w: (L, 1) float32 weight-matrix diagonal.
      x: (L, D) float32 raw features.
      y: (L, 1) float32 raw labels.

    Returns:
      (X̃ (C, D), ỹ (C, 1)) float32 parity data.
    """
    c, l = g.shape
    _, d = x.shape
    if c % block_c != 0 or l % block_l != 0:
        raise ValueError(f"C={c}, L={l} not divisible by ({block_c}, {block_l})")
    grid = (c // block_c, l // block_l)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, block_l), lambda i, j: (i, j)),  # G tile
            pl.BlockSpec((block_l, 1), lambda i, j: (j, 0)),        # w slice
            pl.BlockSpec((block_l, d), lambda i, j: (j, 0)),        # X rows
            pl.BlockSpec((block_l, 1), lambda i, j: (j, 0)),        # y rows
        ],
        out_specs=[
            pl.BlockSpec((block_c, d), lambda i, j: (i, 0)),        # X̃ acc
            pl.BlockSpec((block_c, 1), lambda i, j: (i, 0)),        # ỹ acc
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, d), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
        ],
        interpret=True,
    )(g, w, x, y)
