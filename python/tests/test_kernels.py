"""L1 kernel validation: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (multiples of the block size and block-edge
cases), seeds, and block parameters; assert_allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels import encode, partial_grad, ref

# Keep hypothesis example counts modest: interpret-mode Pallas re-traces per
# shape, and each trace is seconds. Coverage comes from shape diversity.
SETTINGS = dict(max_examples=8, deadline=None)


def rnd(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# partial_grad
# ---------------------------------------------------------------------------

class TestPartialGrad:
    @given(
        lblocks=st.integers(1, 4),
        d=st.sampled_from([8, 128, 256]),
        bm=st.sampled_from([8, 64, 128]),
        seed=st.integers(0, 2**32 - 1),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, lblocks, d, bm, seed):
        rng = np.random.default_rng(seed)
        l = lblocks * bm
        x, beta, y = rnd(rng, l, d), rnd(rng, d, 1), rnd(rng, l, 1)
        got = partial_grad(jnp.asarray(x), jnp.asarray(beta), jnp.asarray(y), block_rows=bm)
        want = ref.partial_grad(x, beta, y)
        scale = max(1.0, float(np.abs(want).max()))
        assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4 * scale, rtol=2e-4)

    def test_single_block(self):
        rng = np.random.default_rng(7)
        x, beta, y = rnd(rng, 128, 64), rnd(rng, 64, 1), rnd(rng, 128, 1)
        got = partial_grad(jnp.asarray(x), jnp.asarray(beta), jnp.asarray(y), block_rows=128)
        assert_allclose(np.asarray(got), np.asarray(ref.partial_grad(x, beta, y)), rtol=2e-4, atol=1e-3)

    def test_zero_row_padding_is_exact(self):
        """Padded (zero) rows must not perturb the gradient."""
        rng = np.random.default_rng(1)
        x, beta, y = rnd(rng, 128, 32), rnd(rng, 32, 1), rnd(rng, 128, 1)
        xp = np.concatenate([x, np.zeros((128, 32), np.float32)])
        yp = np.concatenate([y, np.zeros((128, 1), np.float32)])
        g0 = partial_grad(jnp.asarray(x), jnp.asarray(beta), jnp.asarray(y), block_rows=64)
        g1 = partial_grad(jnp.asarray(xp), jnp.asarray(beta), jnp.asarray(yp), block_rows=64)
        assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-6, atol=1e-6)

    def test_zero_col_padding_is_exact(self):
        """Padded (zero) model columns must yield zero gradient entries."""
        rng = np.random.default_rng(2)
        x, beta, y = rnd(rng, 64, 16), rnd(rng, 16, 1), rnd(rng, 64, 1)
        xp = np.concatenate([x, np.zeros((64, 16), np.float32)], axis=1)
        bp = np.concatenate([beta, np.zeros((16, 1), np.float32)])
        g = np.asarray(partial_grad(jnp.asarray(xp), jnp.asarray(bp), jnp.asarray(y), block_rows=64))
        assert_allclose(g[:16], np.asarray(ref.partial_grad(x, beta, y)), rtol=2e-4, atol=1e-4)
        assert_allclose(g[16:], 0.0, atol=1e-6)

    def test_rejects_misaligned_rows(self):
        x = jnp.zeros((100, 16), jnp.float32)
        with pytest.raises(ValueError, match="divisible"):
            partial_grad(x, jnp.zeros((16, 1)), jnp.zeros((100, 1)), block_rows=64)

    def test_zero_inputs(self):
        g = partial_grad(jnp.zeros((64, 8)), jnp.zeros((8, 1)), jnp.zeros((64, 1)), block_rows=64)
        assert float(jnp.abs(g).max()) == 0.0


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------

class TestEncode:
    @given(
        cblocks=st.integers(1, 3),
        lblocks=st.integers(1, 3),
        d=st.sampled_from([8, 64, 128]),
        blk=st.sampled_from([32, 128]),
        seed=st.integers(0, 2**32 - 1),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, cblocks, lblocks, d, blk, seed):
        rng = np.random.default_rng(seed)
        c, l = cblocks * blk, lblocks * blk
        g, x, y = rnd(rng, c, l), rnd(rng, l, d), rnd(rng, l, 1)
        w = rng.uniform(0, 1, size=(l, 1)).astype(np.float32)
        xt, yt = encode(jnp.asarray(g), jnp.asarray(w), jnp.asarray(x), jnp.asarray(y),
                        block_c=blk, block_l=blk)
        rxt, ryt = ref.encode(g, w, x, y)
        assert_allclose(np.asarray(xt), np.asarray(rxt), rtol=3e-4, atol=3e-4 * max(1.0, float(np.abs(rxt).max())))
        assert_allclose(np.asarray(yt), np.asarray(ryt), rtol=3e-4, atol=3e-4 * max(1.0, float(np.abs(ryt).max())))

    def test_weight_fusion_equals_two_pass(self):
        """G @ (w⊙X) computed fused must equal the unfused two-pass result."""
        rng = np.random.default_rng(3)
        g, x, y = rnd(rng, 64, 64), rnd(rng, 64, 32), rnd(rng, 64, 1)
        w = rng.uniform(size=(64, 1)).astype(np.float32)
        xt, yt = encode(jnp.asarray(g), jnp.asarray(w), jnp.asarray(x), jnp.asarray(y),
                        block_c=32, block_l=32)
        assert_allclose(np.asarray(xt), g @ (w * x), rtol=2e-4, atol=1e-3)
        assert_allclose(np.asarray(yt), g @ (w * y), rtol=2e-4, atol=1e-3)

    def test_linearity_in_generator(self):
        """encode(G1+G2) == encode(G1) + encode(G2) — the property that makes
        composite parity (Eq. 10) equal encoding over the concatenated data."""
        rng = np.random.default_rng(4)
        g1, g2 = rnd(rng, 32, 32), rnd(rng, 32, 32)
        x, y = rnd(rng, 32, 16), rnd(rng, 32, 1)
        w = rng.uniform(size=(32, 1)).astype(np.float32)
        a = encode(jnp.asarray(g1 + g2), jnp.asarray(w), jnp.asarray(x), jnp.asarray(y),
                   block_c=32, block_l=32)
        b1 = encode(jnp.asarray(g1), jnp.asarray(w), jnp.asarray(x), jnp.asarray(y),
                    block_c=32, block_l=32)
        b2 = encode(jnp.asarray(g2), jnp.asarray(w), jnp.asarray(x), jnp.asarray(y),
                    block_c=32, block_l=32)
        assert_allclose(np.asarray(a[0]), np.asarray(b1[0]) + np.asarray(b2[0]), rtol=1e-4, atol=1e-3)
        assert_allclose(np.asarray(a[1]), np.asarray(b1[1]) + np.asarray(b2[1]), rtol=1e-4, atol=1e-3)

    def test_zero_padding_parity_rows(self):
        """Zero generator rows (C padding) produce exactly zero parity."""
        rng = np.random.default_rng(5)
        g = rnd(rng, 32, 32)
        g[16:] = 0.0
        x, y = rnd(rng, 32, 16), rnd(rng, 32, 1)
        w = np.ones((32, 1), np.float32)
        xt, yt = encode(jnp.asarray(g), jnp.asarray(w), jnp.asarray(x), jnp.asarray(y),
                        block_c=16, block_l=16)
        assert float(np.abs(np.asarray(xt)[16:]).max()) == 0.0
        assert float(np.abs(np.asarray(yt)[16:]).max()) == 0.0

    def test_rejects_misaligned(self):
        with pytest.raises(ValueError, match="divisible"):
            encode(jnp.zeros((33, 32)), jnp.zeros((32, 1)), jnp.zeros((32, 8)),
                   jnp.zeros((32, 1)), block_c=32, block_l=32)


# ---------------------------------------------------------------------------
# statistical property behind Eq. (18): GᵀG/c ≈ I for Gaussian G
# ---------------------------------------------------------------------------

class TestCodingIdentity:
    def test_parity_gradient_approximates_weighted_gradient(self):
        """(1/c)X̃ᵀ(X̃β−ỹ) → XᵀW²(Xβ−y) as c grows (weak LLN, Eq. 18)."""
        rng = np.random.default_rng(6)
        l, d = 64, 16
        x, beta, y = rnd(rng, l, d), rnd(rng, d, 1), rnd(rng, l, 1)
        w = rng.uniform(0.3, 1.0, size=(l, 1)).astype(np.float32)
        errs = []
        for c in (128, 1024, 4096):
            g = rng.normal(size=(c, l)).astype(np.float32)
            xt, yt = ref.encode(g, w, x, y)
            parity_grad = np.asarray(xt).T @ (np.asarray(xt) @ beta - np.asarray(yt)) / c
            target = x.T @ ((w ** 2) * (x @ beta - y))
            errs.append(float(np.linalg.norm(parity_grad - target) / np.linalg.norm(target)))
        assert errs[2] < errs[0], f"error should shrink with c: {errs}"
        assert errs[2] < 0.2
