"""L2 model-graph tests: masking/puncturing semantics, update step, NMSE."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rnd(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


class TestDeviceGrad:
    def test_full_mask_equals_plain_gradient(self):
        rng = np.random.default_rng(0)
        x, b, y = rnd(rng, 128, 64), rnd(rng, 64, 1), rnd(rng, 128, 1)
        m = np.ones((128, 1), np.float32)
        got = model.device_grad(jnp.asarray(x), jnp.asarray(b), jnp.asarray(y), jnp.asarray(m))
        assert_allclose(np.asarray(got), ref.partial_grad(x, b, y), rtol=2e-4, atol=1e-3)

    @given(seed=st.integers(0, 2**32 - 1), keep=st.integers(0, 128))
    @settings(max_examples=6, deadline=None)
    def test_puncturing_mask(self, seed, keep):
        """Masked-out rows are excluded exactly (§III-C puncturing)."""
        rng = np.random.default_rng(seed)
        x, b, y = rnd(rng, 128, 32), rnd(rng, 32, 1), rnd(rng, 128, 1)
        m = np.zeros((128, 1), np.float32)
        m[:keep] = 1.0
        got = model.device_grad(jnp.asarray(x), jnp.asarray(b), jnp.asarray(y), jnp.asarray(m))
        want = ref.partial_grad(x[:keep], b, y[:keep]) if keep else np.zeros((32, 1), np.float32)
        scale = max(1.0, float(np.abs(want).max()))
        assert_allclose(np.asarray(got), want, atol=3e-4 * scale, rtol=3e-4)

    def test_mask_scaling_is_quadratic_free(self):
        """mask ∈ {0,1} ⇒ masking X and y once is exact (no mask² effect on
        the residual term, because masked rows have both Xrow=0 and y=0)."""
        rng = np.random.default_rng(1)
        x, b, y = rnd(rng, 64, 16), rnd(rng, 16, 1), rnd(rng, 64, 1)
        m = (rng.uniform(size=(64, 1)) < 0.5).astype(np.float32)
        got = model.device_grad(jnp.asarray(x), jnp.asarray(b), jnp.asarray(y), jnp.asarray(m))
        want = (m * x).T @ ((m * x) @ b - m * y)
        assert_allclose(np.asarray(got), want, rtol=2e-4, atol=1e-3)


class TestServerParityGrad:
    def test_normalization_by_c(self):
        rng = np.random.default_rng(2)
        xt, b, yt = rnd(rng, 128, 32), rnd(rng, 32, 1), rnd(rng, 128, 1)
        inv_c = np.array([[1.0 / 96.0]], np.float32)  # logical c < padded C
        got = model.server_parity_grad(jnp.asarray(xt), jnp.asarray(b), jnp.asarray(yt), jnp.asarray(inv_c))
        want = ref.partial_grad(xt, b, yt) / 96.0
        assert_allclose(np.asarray(got), want, rtol=2e-4, atol=1e-3)


class TestGdStepAndNmse:
    @given(seed=st.integers(0, 2**32 - 1))
    @settings(max_examples=6, deadline=None)
    def test_gd_step(self, seed):
        rng = np.random.default_rng(seed)
        b, g = rnd(rng, 64, 1), rnd(rng, 64, 1)
        lr = np.array([[0.0085 / 7200.0]], np.float32)
        got = model.gd_step(jnp.asarray(b), jnp.asarray(g), jnp.asarray(lr))
        assert_allclose(np.asarray(got), b - lr * g, rtol=1e-6, atol=1e-7)

    def test_nmse_definition(self):
        rng = np.random.default_rng(3)
        bh, bs = rnd(rng, 32, 1), rnd(rng, 32, 1)
        got = float(np.asarray(model.nmse(jnp.asarray(bh), jnp.asarray(bs)))[0, 0])
        want = np.linalg.norm(bh - bs) ** 2 / np.linalg.norm(bs) ** 2
        assert abs(got - want) < 1e-5 * max(1.0, want)

    def test_nmse_zero_at_truth(self):
        b = rnd(np.random.default_rng(4), 16, 1)
        assert float(np.asarray(model.nmse(jnp.asarray(b), jnp.asarray(b)))[0, 0]) == 0.0
