"""AOT pipeline tests: HLO text generation, manifest format, numerics.

These validate the artifact pipeline end to end inside python: lower a
graph to HLO text the way ``aot.py`` does, re-import it as an
XlaComputation, execute on the CPU backend, and compare against ref.py —
i.e. the same round trip the rust runtime performs.
"""

import os
import tempfile

import numpy as np
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


def rnd(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


def roundtrip(fn, *args):
    """Lower → HLO text (the artifact format) + execute the lowered graph.

    jax 0.8.2's in-process client cannot re-load parsed HLO text (that path
    is exercised by the rust runtime integration tests instead); here we
    validate that the text is well-formed HLO and that the *lowered* graph
    — the exact graph serialized into the artifact — computes ref numbers.
    """
    lowered = jax.jit(fn).lower(*(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule") and "ENTRY" in text
    # parameter/root shapes in the HLO text must match the operands
    for a in args:
        dims = ",".join(map(str, a.shape))
        assert f"f32[{dims}]" in text, f"missing operand shape f32[{dims}]"
    out = jax.jit(fn)(*args)
    return [np.asarray(o) for o in (out if isinstance(out, (tuple, list)) else (out,))]


class TestRoundTrip:
    def test_grad_roundtrip_matches_ref(self):
        rng = np.random.default_rng(0)
        x, b, y = rnd(rng, 128, 64), rnd(rng, 64, 1), rnd(rng, 128, 1)
        m = np.ones((128, 1), np.float32)
        (out,) = roundtrip(model.device_grad, x, b, y, m)
        assert_allclose(out, ref.partial_grad(x, b, y), rtol=3e-4, atol=1e-3)

    def test_encode_roundtrip_matches_ref(self):
        rng = np.random.default_rng(1)
        g, x, y = rnd(rng, 128, 128), rnd(rng, 128, 32), rnd(rng, 128, 1)
        w = rng.uniform(size=(128, 1)).astype(np.float32)
        xt, yt = roundtrip(model.encode_parity, g, w, x, y)
        rxt, ryt = ref.encode(g, w, x, y)
        assert_allclose(xt, rxt, rtol=3e-4, atol=3e-3)
        assert_allclose(yt, ryt, rtol=3e-4, atol=3e-3)


class TestBuild:
    def test_build_writes_manifest_and_artifacts(self):
        with tempfile.TemporaryDirectory() as td:
            aot.build(td, only=["grad_dev_s", "gd_step"])
            files = set(os.listdir(td))
            assert {"grad_dev_s.hlo.txt", "gd_step.hlo.txt", "manifest.txt"} <= files
            lines = [l for l in open(os.path.join(td, "manifest.txt"))
                     if l.strip() and not l.startswith("#")]
            assert len(lines) == 2
            by_name = {l.split()[0]: l.split() for l in lines}
            assert by_name["grad_dev_s"][1] == "grad"
            assert by_name["grad_dev_s"][2] == "grad_dev_s.hlo.txt"
            assert [int(v) for v in by_name["grad_dev_s"][3:]] == [128, 128]

    def test_artifact_registry_shapes_consistent(self):
        for name, (kind, _fn, args, dims) in aot.ARTIFACTS.items():
            if kind == "grad":
                l, d = dims
                assert args[0].shape == (l, d) and args[1].shape == (d, 1)
            elif kind == "pgrad":
                c, d = dims
                assert args[0].shape == (c, d) and args[3].shape == (1, 1)
            elif kind == "encode":
                c, l, d = dims
                assert args[0].shape == (c, l) and args[2].shape == (l, d)

    def test_hlo_text_is_plain_hlo(self):
        """Guard the interchange contract: text starts with HloModule and
        contains no stablehlo dialect ops (rust's parser is HLO-only)."""
        lowered = jax.jit(model.gd_step).lower(
            jax.ShapeDtypeStruct((8, 1), jnp.float32),
            jax.ShapeDtypeStruct((8, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        )
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "stablehlo." not in text
