#!/usr/bin/env bash
# CI scale smoke: prove the million-device sim machinery holds its budget
# at the 100k rung. Runs `cfl sweep --scenario scale-ci` (the scaling
# ladder's single 100k-device cell: lean data, participation count:256,
# 24-tier ladder, fan-in-32 aggregation, 64-point traces) under a
# wall-clock budget, then checks the kernel-reported peak RSS the CLI
# prints (Linux VmHWM) against a memory budget, and pins the report
# schema against bench/scale_baseline.json with `cfl bench-check`.
#
# Budgets are deliberately loose multiples of the expected cost (a 100k
# fleet should take single-digit seconds and tens of MiB): the gate is
# for O(fleet)-per-epoch regressions — which blow these numbers up by
# orders of magnitude — not for host jitter.
#
# Usage: scripts/scale_smoke.sh
# Env:   CFL_BIN overrides the binary (default target/{release,debug}/cfl)
#        SCALE_WALL_BUDGET_S (default 300), SCALE_RSS_BUDGET_MIB (default 2048)
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${CFL_BIN:-}
if [[ -z "$BIN" ]]; then
    for candidate in target/release/cfl target/debug/cfl; do
        if [[ -x "$candidate" ]]; then
            BIN=$candidate
            break
        fi
    done
fi
if [[ -z "${BIN:-}" || ! -x "$BIN" ]]; then
    echo "scale_smoke: cfl binary not built (run cargo build --release first)" >&2
    exit 1
fi

WALL_BUDGET=${SCALE_WALL_BUDGET_S:-300}
RSS_BUDGET_MIB=${SCALE_RSS_BUDGET_MIB:-2048}
OUT=${SCALE_OUT:-scale_out}
LOG="$OUT/scale_smoke.log"
mkdir -p "$OUT"

# `timeout` turns a hung/quadratic run into a clean failure instead of a
# 6-hour CI job; the sweep itself is deterministic (sim backend)
start=$(date +%s)
if ! timeout "$WALL_BUDGET" "$BIN" sweep --scenario scale-ci --quiet \
    --out "$OUT" --bench-out BENCH_scale.json | tee "$LOG"; then
    echo "scale_smoke: sweep failed or exceeded the ${WALL_BUDGET}s wall budget" >&2
    exit 1
fi
elapsed=$(( $(date +%s) - start ))
echo "scale_smoke: 100k-device scenario finished in ${elapsed}s (budget ${WALL_BUDGET}s)"

# the CLI prints the kernel's VmHWM high-water mark after the sweep; on
# platforms without /proc the line is absent and the RSS gate self-skips
rss_line=$(grep -E '^peak RSS: ' "$LOG" || true)
if [[ -n "$rss_line" ]]; then
    rss_mib=$(echo "$rss_line" | awk '{print $3}')
    over=$(awk -v r="$rss_mib" -v b="$RSS_BUDGET_MIB" 'BEGIN {print (r > b) ? 1 : 0}')
    if [[ "$over" == "1" ]]; then
        echo "scale_smoke: peak RSS ${rss_mib} MiB exceeds the ${RSS_BUDGET_MIB} MiB budget" >&2
        exit 1
    fi
    echo "scale_smoke: peak RSS ${rss_mib} MiB (budget ${RSS_BUDGET_MIB} MiB)"
else
    echo "scale_smoke: no peak RSS line (non-Linux host?) — RSS gate skipped"
fi

# pin the report schema + scenario id; the scale cells run epoch-capped
# (target 0), so the baseline records no gain and the bench gate is the
# schema/id check, not a gain floor
"$BIN" bench-check --report BENCH_scale.json --baseline bench/scale_baseline.json \
    --tolerance 0.2 --wall-tolerance off
echo "scale_smoke: ok"
