#!/usr/bin/env bash
# Conformance smoke: run the cross-backend conformance suite and assert
# the verdict artifacts stream out. The quick tier (default) gates the
# CI check matrix; `conformance_smoke.sh full` runs the whole matrix
# (tcp legs everywhere, medium fixtures, all fault cells) for the
# non-blocking CI job.
#
# Usage: scripts/conformance_smoke.sh [full]
# Env: CFL_BIN overrides the binary (default: target/{release,debug}/cfl),
#      CONFORMANCE_OUT overrides the scratch directory (default: conformance_out).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${CFL_BIN:-}
if [[ -z "$BIN" ]]; then
    for candidate in target/release/cfl target/debug/cfl; do
        if [[ -x "$candidate" ]]; then
            BIN=$candidate
            break
        fi
    done
fi
if [[ -z "${BIN:-}" || ! -x "$BIN" ]]; then
    echo "conformance_smoke: cfl binary not built (run cargo build --release first)" >&2
    exit 1
fi

TIER=${1:-quick}
OUT=${CONFORMANCE_OUT:-conformance_out}
rm -rf "$OUT"
mkdir -p "$OUT"

ARGS=(conformance --out "$OUT")
if [[ "$TIER" == "full" ]]; then
    ARGS+=(--full)
fi

"$BIN" "${ARGS[@]}"

# the artifacts stream per check: a header plus one CSV row / one JSONL
# line per executed check
for f in "$OUT/conformance.csv" "$OUT/conformance.jsonl"; do
    if [[ ! -s "$f" ]]; then
        echo "conformance_smoke: missing artifact $f" >&2
        exit 1
    fi
done
rows=$(($(wc -l < "$OUT/conformance.csv") - 1))
lines=$(wc -l < "$OUT/conformance.jsonl")
if [[ "$rows" -lt 1 || "$rows" -ne "$lines" ]]; then
    echo "conformance_smoke: artifact mismatch ($rows CSV rows vs $lines JSONL lines)" >&2
    exit 1
fi
if grep -q ',FAIL,' "$OUT/conformance.csv"; then
    echo "conformance_smoke: FAIL rows present in $OUT/conformance.csv" >&2
    exit 1
fi

echo "conformance_smoke ok: $TIER tier, $rows checks recorded"
