#!/usr/bin/env bash
# Rejoin smoke test: a TCP device SIGKILLed mid-run and restarted with
# `--retry` must rejoin the fleet, receive the current model, and finish
# the run inside the coded gather set (not demoted to parity-only).
#
# Flow: 1 `cfl serve` coordinator + 3 `cfl device` workers on loopback;
# one worker is SIGKILLed once training is underway, then restarted with
# the same --id and --retry. The serve report must show the disconnect,
# the rejoin, full final membership, and a converged model
# (--check-nmse makes serve exit nonzero otherwise).
#
# Sandboxes that deny socket bind are detected with `cfl serve --probe`
# and skipped with a notice — the test needs real sockets or nothing.
#
# Env: CFL_BIN overrides the binary (default: target/{release,debug}/cfl).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${CFL_BIN:-}
if [[ -z "$BIN" ]]; then
    for candidate in target/release/cfl target/debug/cfl; do
        if [[ -x "$candidate" ]]; then
            BIN=$candidate
            break
        fi
    done
fi
if [[ -z "${BIN:-}" || ! -x "$BIN" ]]; then
    echo "rejoin_smoke: cfl binary not built (run cargo build first)" >&2
    exit 1
fi

if ! "$BIN" serve --probe --bind 127.0.0.1:0 >/dev/null 2>&1; then
    echo "rejoin_smoke: sandbox denies loopback bind; skipping the rejoin smoke test"
    exit 0
fi

tmp=$(mktemp -d)
device_pids=()
cleanup() {
    for pid in "${device_pids[@]:-}"; do
        kill "$pid" 2>/dev/null || true
    done
    rm -rf "$tmp"
}
trap cleanup EXIT

# target-nmse 0 disables early stop so the run reliably spans the kill +
# restart below; time-scale 0.2 paces every epoch with milliseconds of
# real slept delay (the slowest modeled link alone is ≥ ~2 ms), so the
# run lasts several seconds and "mid-run" is wall-clock reachable.
# --check-nmse still gates the final model: a fleet that lost a shard
# for good would converge visibly worse.
port_file="$tmp/addr"
"$BIN" serve --bind 127.0.0.1:0 --port-file "$port_file" --devices 3 \
    --epochs 2000 --seed 11 --time-scale 0.2 --target-nmse 0 \
    --skip-uncoded --check-nmse 0.05 --quiet >"$tmp/serve.log" 2>&1 &
serve_pid=$!

for _ in $(seq 100); do
    [[ -s "$port_file" ]] && break
    sleep 0.1
done
if [[ ! -s "$port_file" ]]; then
    echo "rejoin_smoke: serve never published its address" >&2
    cat "$tmp/serve.log" >&2
    exit 1
fi
addr=$(tr -d '[:space:]' <"$port_file")

"$BIN" device --connect "$addr" --id 0 --retry --quiet &
device_pids+=($!)
"$BIN" device --connect "$addr" --id 1 --retry --quiet &
device_pids+=($!)
"$BIN" device --connect "$addr" --id 2 --retry --quiet &
victim_pid=$!
device_pids+=($victim_pid)

# let training get underway, then SIGKILL one device mid-run
sleep 2
if ! kill -0 "$serve_pid" 2>/dev/null; then
    echo "rejoin_smoke: serve exited before the kill — run too short for the smoke" >&2
    cat "$tmp/serve.log" >&2
    exit 1
fi
kill -9 "$victim_pid"
echo "rejoin_smoke: SIGKILLed device 2 (pid $victim_pid) mid-run"
sleep 0.5

# restart it with the same slot id: --retry re-claims the slot and the
# coordinator restores it to the coded gather set
"$BIN" device --connect "$addr" --id 2 --retry --quiet &
device_pids+=($!)
echo "rejoin_smoke: restarted device 2 with --retry"

if ! wait "$serve_pid"; then
    echo "rejoin_smoke: serve failed (final NMSE gate or transport fault)" >&2
    cat "$tmp/serve.log" >&2
    exit 1
fi

report=$(grep "live cfl" "$tmp/serve.log" || true)
if [[ -z "$report" ]]; then
    echo "rejoin_smoke: no coded run report in the serve log" >&2
    cat "$tmp/serve.log" >&2
    exit 1
fi
echo "rejoin_smoke: $report"

# the report must show the churn and the recovery: at least one
# disconnect, at least one rejoin, and a final gather set of 3/3 —
# i.e. the restarted device ended the run coded, not parity-only
if ! grep -Eq "disconnects=[1-9]" <<<"$report"; then
    echo "rejoin_smoke: the SIGKILL was never observed as a disconnect" >&2
    exit 1
fi
if ! grep -Eq "rejoins=[1-9]" <<<"$report"; then
    echo "rejoin_smoke: the restarted device never rejoined" >&2
    exit 1
fi
if ! grep -q "members=3/3" <<<"$report"; then
    echo "rejoin_smoke: full coded coverage was not restored" >&2
    exit 1
fi

# surviving devices exit on the coordinator's Shutdown
for pid in "${device_pids[@]}"; do
    wait "$pid" 2>/dev/null || true
done
device_pids=()
echo "rejoin_smoke ok: device 2 was killed, rejoined, and finished inside the coded gather set"
