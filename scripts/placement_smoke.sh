#!/usr/bin/env bash
# Placement smoke test: the cross-host fleet path, on one machine.
#
# Leg 1 — `cfl sweep --live --transport tcp --placement` with an
# all-local manifest: the sweep must form its fleet through the
# placement machinery (one multi-slot child process) and complete.
#
# Leg 2 — `cfl serve --placement` with a manifest that marks two slots
# remote. The script itself plays the remote host: one
# `cfl device --slots 1,2 --retry` process claiming both slots over a
# single connection. It is SIGKILLed mid-run and restarted; the serve
# report must show the disconnects, the rejoins, full final membership,
# and a converged model (--check-nmse makes serve exit nonzero
# otherwise).
#
# Sandboxes that deny socket bind are detected with `cfl serve --probe`
# and skipped with a notice — the test needs real sockets or nothing.
#
# Env: CFL_BIN overrides the binary (default: target/{release,debug}/cfl).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${CFL_BIN:-}
if [[ -z "$BIN" ]]; then
    for candidate in target/release/cfl target/debug/cfl; do
        if [[ -x "$candidate" ]]; then
            BIN=$candidate
            break
        fi
    done
fi
if [[ -z "${BIN:-}" || ! -x "$BIN" ]]; then
    echo "placement_smoke: cfl binary not built (run cargo build first)" >&2
    exit 1
fi

if ! "$BIN" serve --probe --bind 127.0.0.1:0 >/dev/null 2>&1; then
    echo "placement_smoke: sandbox denies loopback bind; skipping the placement smoke test"
    exit 0
fi

tmp=$(mktemp -d)
device_pids=()
cleanup() {
    for pid in "${device_pids[@]:-}"; do
        kill "$pid" 2>/dev/null || true
    done
    rm -rf "$tmp"
}
trap cleanup EXIT

# ---------------------------------------------------------------- leg 1
# an all-local manifest: every slot on this machine, formed through the
# placement path (one multi-slot child) rather than one child per slot
cat >"$tmp/local.ini" <<'EOF'
[placement]
device.0 = local
device.1 = local
EOF

if ! "$BIN" sweep --live --transport tcp --placement "$tmp/local.ini" \
    --devices 4 --epochs 25 --time-scale 1e-4 --axis nu=0 \
    --skip-uncoded --out "$tmp/sweepout" --quiet >"$tmp/sweep.log" 2>&1; then
    echo "placement_smoke: placed sweep failed" >&2
    cat "$tmp/sweep.log" >&2
    exit 1
fi
echo "placement_smoke: all-local placed sweep completed"

# ---------------------------------------------------------------- leg 2
# a mixed manifest: slot 0 local, slots 1+2 on "hostB" — played by this
# script as one multi-slot device process
cat >"$tmp/mixed.ini" <<'EOF'
[placement]
device.1 = hostB
device.2 = hostB
EOF

# target-nmse 0 disables early stop so the run reliably spans the kill +
# restart below; time-scale 0.2 paces epochs with real slept delay so
# "mid-run" is wall-clock reachable (see rejoin_smoke.sh)
port_file="$tmp/addr"
"$BIN" serve --bind 127.0.0.1:0 --port-file "$port_file" --devices 3 \
    --placement "$tmp/mixed.ini" \
    --epochs 2000 --seed 11 --time-scale 0.2 --target-nmse 0 \
    --skip-uncoded --check-nmse 0.05 --quiet >"$tmp/serve.log" 2>&1 &
serve_pid=$!

for _ in $(seq 100); do
    [[ -s "$port_file" ]] && break
    sleep 0.1
done
if [[ ! -s "$port_file" ]]; then
    echo "placement_smoke: serve never published its address" >&2
    cat "$tmp/serve.log" >&2
    exit 1
fi
addr=$(tr -d '[:space:]' <"$port_file")

# "hostB": both of its slots over one connection
"$BIN" device --connect "$addr" --slots 1,2 --retry --quiet &
victim_pid=$!
device_pids+=($victim_pid)

# let training get underway, then SIGKILL the whole remote host
sleep 2
if ! kill -0 "$serve_pid" 2>/dev/null; then
    echo "placement_smoke: serve exited before the kill — run too short for the smoke" >&2
    cat "$tmp/serve.log" >&2
    exit 1
fi
kill -9 "$victim_pid"
echo "placement_smoke: SIGKILLed the 2-slot host process (pid $victim_pid) mid-run"
sleep 0.5

# restart it: --retry re-claims both slots over a fresh connection
"$BIN" device --connect "$addr" --slots 1,2 --retry --quiet &
device_pids+=($!)
echo "placement_smoke: restarted the 2-slot host with --retry"

if ! wait "$serve_pid"; then
    echo "placement_smoke: serve failed (final NMSE gate or transport fault)" >&2
    cat "$tmp/serve.log" >&2
    exit 1
fi

report=$(grep "live cfl" "$tmp/serve.log" || true)
if [[ -z "$report" ]]; then
    echo "placement_smoke: no coded run report in the serve log" >&2
    cat "$tmp/serve.log" >&2
    exit 1
fi
echo "placement_smoke: $report"

# killing the host loses two slots at once; both must come back and the
# final gather set must be whole — coded coverage, not parity-only
if ! grep -Eq "disconnects=[2-9]" <<<"$report"; then
    echo "placement_smoke: the SIGKILL was not observed as two slot disconnects" >&2
    exit 1
fi
if ! grep -Eq "rejoins=[2-9]" <<<"$report"; then
    echo "placement_smoke: the restarted host never rejoined both slots" >&2
    exit 1
fi
if ! grep -q "members=3/3" <<<"$report"; then
    echo "placement_smoke: full coded coverage was not restored" >&2
    exit 1
fi

# surviving processes exit on the coordinator's Shutdown
for pid in "${device_pids[@]}"; do
    wait "$pid" 2>/dev/null || true
done
device_pids=()
echo "placement_smoke ok: a 2-slot host was killed, rejoined, and the fleet finished coded"
