#!/usr/bin/env bash
# TCP loopback smoke test: 1 `cfl serve` coordinator + 2 `cfl device`
# worker processes on 127.0.0.1, asserting the run converges
# (--check-nmse makes serve exit nonzero otherwise).
#
# Sandboxes that deny socket bind are detected with `cfl serve --probe`
# and skipped with a notice — the test needs real sockets or nothing.
#
# Env: CFL_BIN overrides the binary (default: target/{release,debug}/cfl).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${CFL_BIN:-}
if [[ -z "$BIN" ]]; then
    for candidate in target/release/cfl target/debug/cfl; do
        if [[ -x "$candidate" ]]; then
            BIN=$candidate
            break
        fi
    done
fi
if [[ -z "${BIN:-}" || ! -x "$BIN" ]]; then
    echo "smoke_loopback: cfl binary not built (run cargo build first)" >&2
    exit 1
fi

if ! "$BIN" serve --probe --bind 127.0.0.1:0 >/dev/null 2>&1; then
    echo "smoke_loopback: sandbox denies loopback bind; skipping the socket smoke test"
    exit 0
fi

tmp=$(mktemp -d)
device_pids=()
cleanup() {
    for pid in "${device_pids[@]:-}"; do
        kill "$pid" 2>/dev/null || true
    done
    rm -rf "$tmp"
}
trap cleanup EXIT

port_file="$tmp/addr"
"$BIN" serve --bind 127.0.0.1:0 --port-file "$port_file" --devices 2 \
    --epochs 400 --seed 7 --time-scale 1e-4 --skip-uncoded \
    --check-nmse 0.8 --quiet >"$tmp/serve.log" 2>&1 &
serve_pid=$!

for _ in $(seq 100); do
    [[ -s "$port_file" ]] && break
    sleep 0.1
done
if [[ ! -s "$port_file" ]]; then
    echo "smoke_loopback: serve never published its address" >&2
    cat "$tmp/serve.log" >&2
    exit 1
fi
addr=$(tr -d '[:space:]' <"$port_file")

"$BIN" device --connect "$addr" --id 0 --quiet &
device_pids+=($!)
"$BIN" device --connect "$addr" --id 1 --quiet &
device_pids+=($!)

if ! wait "$serve_pid"; then
    echo "smoke_loopback: serve failed" >&2
    cat "$tmp/serve.log" >&2
    exit 1
fi
# devices exit on the coordinator's Shutdown
for pid in "${device_pids[@]}"; do
    wait "$pid"
done
device_pids=()
echo "smoke_loopback: 1 serve + 2 device processes converged over TCP loopback"
