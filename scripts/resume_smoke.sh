#!/usr/bin/env bash
# Kill-and-resume smoke: a sim-backend sweep interrupted halfway and
# restarted with --resume must produce a per-scenario CSV *and* a
# sweep_report.json byte-identical to an uninterrupted run, and
# --traces-dir must emit one per-epoch trace file per run (CFL +
# uncoded baseline per scenario).
#
# The "kill" is simulated deterministically: run the full grid once,
# truncate the CSV to the header plus half the scenario rows and the
# record sidecar to the same boundary (what a real kill leaves behind,
# since both stream to disk in grid order), then re-run with --resume
# and compare.
#
# Usage: scripts/resume_smoke.sh
# Env: CFL_BIN overrides the binary (default: target/{release,debug}/cfl),
#      RESUME_OUT overrides the scratch directory (default: resume_out).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${CFL_BIN:-}
if [[ -z "$BIN" ]]; then
    for candidate in target/release/cfl target/debug/cfl; do
        if [[ -x "$candidate" ]]; then
            BIN=$candidate
            break
        fi
    done
fi
if [[ -z "${BIN:-}" || ! -x "$BIN" ]]; then
    echo "resume_smoke: cfl binary not built (run cargo build --release first)" >&2
    exit 1
fi

OUT=${RESUME_OUT:-resume_out}
rm -rf "$OUT"
mkdir -p "$OUT/full" "$OUT/resumed"

# fixed seed + fixed grid on the deterministic sim backend: the reports
# are a pure function of this command line
ARGS=(sweep --seed 2020 --axis nu=0,0.2,0.4 --axis delta=0.1,0.15 --workers 2 --quiet)

"$BIN" "${ARGS[@]}" --out "$OUT/full" --traces-dir "$OUT/full/traces"

CSV=$OUT/full/sweep_scenarios.csv
SIDECAR=$OUT/full/sweep_scenarios.records.jsonl
rows=$(($(wc -l < "$CSV") - 1))
keep=$((rows / 2))
echo "resume_smoke: $rows scenarios ran; truncating the CSV to $keep to simulate a kill"
head -n $((1 + keep)) "$CSV" > "$OUT/resumed/sweep_scenarios.csv"
# the record sidecar streams in lockstep with the CSV (no header line) —
# a real kill truncates both at the same scenario boundary
head -n "$keep" "$SIDECAR" > "$OUT/resumed/sweep_scenarios.records.jsonl"

"$BIN" "${ARGS[@]}" --out "$OUT/resumed" \
    --resume "$OUT/resumed/sweep_scenarios.csv" --traces-dir "$OUT/resumed/traces"

cmp "$CSV" "$OUT/resumed/sweep_scenarios.csv" || {
    echo "resume_smoke: resumed CSV differs from the uninterrupted run" >&2
    exit 1
}

# with the sidecar recovered, the resumed run regenerates the JSON report
# from recovered + fresh records — byte-identical to the full run's
cmp "$OUT/full/sweep_report.json" "$OUT/resumed/sweep_report.json" || {
    echo "resume_smoke: resumed sweep_report.json differs from the uninterrupted run" >&2
    exit 1
}

# one CFL + one uncoded trace per scenario in the full run; the resumed
# run only re-exports the scenarios it actually re-ran
expected=$((rows * 2))
got=$(ls "$OUT/full/traces" | wc -l)
if [[ "$got" -ne "$expected" ]]; then
    echo "resume_smoke: expected $expected trace files, got $got" >&2
    exit 1
fi
resumed_traces=$(ls "$OUT/resumed/traces" | wc -l)
if [[ "$resumed_traces" -ne $(((rows - keep) * 2)) ]]; then
    echo "resume_smoke: resumed run exported $resumed_traces trace files, expected $(((rows - keep) * 2))" >&2
    exit 1
fi

echo "resume_smoke ok: resumed CSV + JSON report byte-identical ($rows scenarios, $keep recovered, $got traces)"
