#!/usr/bin/env bash
# Repo gate: formatting, lints, tests. Run before pushing; CI runs the
# same script (.github/workflows/ci.yml).
#
# fmt/clippy are skipped with a notice when the component is not
# installed (offline sandboxes ship a bare toolchain); when present they
# are enforced strictly.
set -euo pipefail
cd "$(dirname "$0")/.."

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check"
    cargo fmt --all -- --check
else
    echo "== rustfmt not installed; skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    echo "== clippy not installed; skipping lints"
fi

echo "== cargo doc --no-deps (deny warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo test -q (unit + integration + doctests)"
cargo test -q

# repo-native static analysis (docs/ANALYSIS.md): any finding or stale
# allow fails; also validates the `cfl lint --json` JSONL schema. The
# test run above built the debug binary lint_check.sh picks up.
./scripts/lint_check.sh

# sockets permitting (the script probes bind and skips with a notice in
# sandboxes that deny it), exercise the real-process TCP path too.
# CFL_SKIP_SMOKE=1 skips it here (CI runs it as its own workflow step).
if [[ "${CFL_SKIP_SMOKE:-0}" = "1" ]]; then
    echo "== loopback socket smoke skipped (CFL_SKIP_SMOKE=1)"
else
    echo "== loopback socket smoke (cfl serve + cfl device)"
    ./scripts/smoke_loopback.sh
fi

echo "OK"
