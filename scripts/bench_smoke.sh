#!/usr/bin/env bash
# CI bench smoke: run a tiny fixed sweep (3 heterogeneity scenarios on
# the deterministic sim backend), write the compact BENCH_ci.json report
# (coding gain + wall time per scenario), and gate it against the
# committed bench/baseline.json — a >20% coding-gain drop fails. The
# wall-clock gate arms against a same-host calibration pass: the sweep
# runs twice, pass 1 records this machine's throughput as the wall
# baseline, and pass 2 must hold ≥50% of it (`bench-check
# --wall-tolerance 0.5`). Two back-to-back identical sweeps halving in
# throughput is a real regression (debug logging left on, an O(n²)
# slip), never host jitter — and self-calibration keeps the committed
# baseline portable across CI hardware. The sweep also exports JSONL
# events; every line must parse as JSON and carry the required schema
# keys.
#
# Usage:
#   scripts/bench_smoke.sh                    # run + check (the CI path)
#   scripts/bench_smoke.sh --update-baseline  # run + refresh the baseline
#
# Env: CFL_BIN overrides the binary (default: target/{release,debug}/cfl),
#      BENCH_OUT overrides the sweep report directory (default: bench_out).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${CFL_BIN:-}
if [[ -z "$BIN" ]]; then
    for candidate in target/release/cfl target/debug/cfl; do
        if [[ -x "$candidate" ]]; then
            BIN=$candidate
            break
        fi
    done
fi
if [[ -z "${BIN:-}" || ! -x "$BIN" ]]; then
    echo "bench_smoke: cfl binary not built (run cargo build --release first)" >&2
    exit 1
fi

OUT=${BENCH_OUT:-bench_out}
# fixed seed + fixed grid: the gains are a deterministic function of this
# command line (modulo libm differences across platforms, which the 20%
# tolerance absorbs comfortably)
"$BIN" sweep --seed 2020 --axis nu=0,0.2,0.4 --workers 2 \
    --out "$OUT" --bench-out BENCH_ci.json --quiet \
    --events-out "$OUT/events"

# --- JSONL event export: structural validation -------------------------
shopt -s nullglob
event_files=("$OUT"/events/*.events.jsonl)
shopt -u nullglob
if [[ ${#event_files[@]} -eq 0 ]]; then
    echo "bench_smoke: no *.events.jsonl files written under $OUT/events" >&2
    exit 1
fi
if command -v python3 >/dev/null 2>&1; then
    python3 - "${event_files[@]}" <<'PY'
import json, sys

required = {"seq", "t_us", "level", "event", "kind"}
levels = {"error", "warn", "info", "debug", "trace"}
total = 0
for path in sys.argv[1:]:
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as exc:
                sys.exit(f"{path}:{lineno}: not valid JSON: {exc}")
            missing = required - rec.keys()
            if missing:
                sys.exit(f"{path}:{lineno}: missing keys {sorted(missing)}")
            if rec["level"] not in levels:
                sys.exit(f"{path}:{lineno}: bad level {rec['level']!r}")
            total += 1
if total == 0:
    sys.exit("bench_smoke: event files exist but contain no records")
print(f"bench_smoke: {total} JSONL event records validated "
      f"across {len(sys.argv) - 1} file(s)")
PY
else
    # minimal fallback: every non-empty line must look like a JSON object
    # carrying the required keys (no python3 in this environment)
    for f in "${event_files[@]}"; do
        while IFS= read -r line; do
            [[ -z "$line" ]] && continue
            if [[ "$line" != \{* || "$line" != *\} ]]; then
                echo "bench_smoke: $f: line is not a JSON object: $line" >&2
                exit 1
            fi
            for key in '"seq"' '"t_us"' '"level"' '"event"' '"kind"'; do
                if [[ "$line" != *"$key"* ]]; then
                    echo "bench_smoke: $f: line missing $key: $line" >&2
                    exit 1
                fi
            done
        done < "$f"
    done
    echo "bench_smoke: JSONL events spot-checked (python3 unavailable)"
fi

# --- bench report: wall-clock fields must be present -------------------
for field in '"epochs_per_sec"' '"phases"'; do
    if ! grep -q "$field" BENCH_ci.json; then
        echo "bench_smoke: BENCH_ci.json is missing the $field field" >&2
        exit 1
    fi
done

if [[ "${1:-}" == "--update-baseline" ]]; then
    mkdir -p bench
    cp BENCH_ci.json bench/baseline.json
    echo "bench_smoke: bench/baseline.json refreshed from this run"
    exit 0
fi

# gate one: coding gains against the committed (portable) baseline
"$BIN" bench-check --report BENCH_ci.json --baseline bench/baseline.json --tolerance 0.2

# gate two: wall-clock throughput against this host's own calibration
# pass — pass 1 becomes the wall baseline, pass 2 re-runs the identical
# deterministic sweep and must keep ≥50% of pass 1's epochs/s (gains are
# a pure function of the grid, so the gain leg of this check is exact)
cp BENCH_ci.json "$OUT/BENCH_calib.json"
"$BIN" sweep --seed 2020 --axis nu=0,0.2,0.4 --workers 2 \
    --out "$OUT/pass2" --bench-out BENCH_ci.json --quiet
"$BIN" bench-check --report BENCH_ci.json --baseline "$OUT/BENCH_calib.json" \
    --tolerance 0.2 --wall-tolerance 0.5
