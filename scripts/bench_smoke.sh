#!/usr/bin/env bash
# CI bench smoke: run a tiny fixed sweep (3 heterogeneity scenarios on
# the deterministic sim backend), write the compact BENCH_ci.json report
# (coding gain + wall time per scenario), and gate it against the
# committed bench/baseline.json — a >20% coding-gain drop fails.
#
# Usage:
#   scripts/bench_smoke.sh                    # run + check (the CI path)
#   scripts/bench_smoke.sh --update-baseline  # run + refresh the baseline
#
# Env: CFL_BIN overrides the binary (default: target/{release,debug}/cfl),
#      BENCH_OUT overrides the sweep report directory (default: bench_out).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${CFL_BIN:-}
if [[ -z "$BIN" ]]; then
    for candidate in target/release/cfl target/debug/cfl; do
        if [[ -x "$candidate" ]]; then
            BIN=$candidate
            break
        fi
    done
fi
if [[ -z "${BIN:-}" || ! -x "$BIN" ]]; then
    echo "bench_smoke: cfl binary not built (run cargo build --release first)" >&2
    exit 1
fi

OUT=${BENCH_OUT:-bench_out}
# fixed seed + fixed grid: the gains are a deterministic function of this
# command line (modulo libm differences across platforms, which the 20%
# tolerance absorbs comfortably)
"$BIN" sweep --seed 2020 --axis nu=0,0.2,0.4 --workers 2 \
    --out "$OUT" --bench-out BENCH_ci.json --quiet

if [[ "${1:-}" == "--update-baseline" ]]; then
    mkdir -p bench
    cp BENCH_ci.json bench/baseline.json
    echo "bench_smoke: bench/baseline.json refreshed from this run"
    exit 0
fi

"$BIN" bench-check --report BENCH_ci.json --baseline bench/baseline.json --tolerance 0.2
