#!/usr/bin/env bash
# CI lint gate: run the repo-native static analyzer (`cfl lint`, rules in
# docs/ANALYSIS.md) over the tree — any finding or stale allow fails the
# run — then validate the machine surface: `cfl lint --json` must emit
# line-oriented JSONL where every record is a `finding` with its full
# span (rule/file/line/col/message) or the single trailing `summary`.
#
# Env: CFL_BIN overrides the binary (default: target/{release,debug}/cfl).
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${CFL_BIN:-}
if [[ -z "$BIN" ]]; then
    for candidate in target/release/cfl target/debug/cfl; do
        if [[ -x "$candidate" ]]; then
            BIN=$candidate
            break
        fi
    done
fi
if [[ -z "${BIN:-}" || ! -x "$BIN" ]]; then
    echo "lint_check: cfl binary not built (run cargo build first)" >&2
    exit 1
fi

echo "== cfl lint"
"$BIN" lint

# --- JSONL schema validation ------------------------------------------
# the text pass above already proved the tree is clean, so the JSON pass
# must agree: parseable lines, exactly one summary (the last line), and
# zero findings / stale allows reported in it
json=$("$BIN" lint --json)
if command -v python3 >/dev/null 2>&1; then
    LINT_JSON="$json" python3 - <<'PY'
import json, os, sys

finding_keys = {"kind", "rule", "file", "line", "col", "message"}
summary_keys = {"kind", "files", "rules", "findings", "stale_allows"}
lines = [l for l in os.environ["LINT_JSON"].splitlines() if l.strip()]
if not lines:
    sys.exit("lint_check: --json emitted no lines")
summaries = 0
for lineno, line in enumerate(lines, 1):
    try:
        rec = json.loads(line)
    except json.JSONDecodeError as exc:
        sys.exit(f"lint --json line {lineno}: not valid JSON: {exc}")
    if rec.get("kind") == "summary":
        summaries += 1
        missing = summary_keys - rec.keys()
        if missing:
            sys.exit(f"lint --json line {lineno}: summary missing {sorted(missing)}")
        if lineno != len(lines):
            sys.exit("lint_check: summary must be the final line")
        if rec["findings"] != 0 or rec["stale_allows"] != 0:
            sys.exit(f"lint_check: summary reports problems: {rec}")
        if rec["files"] <= 0 or rec["rules"] <= 0:
            sys.exit(f"lint_check: implausible summary counts: {rec}")
    elif rec.get("kind") == "finding":
        missing = finding_keys - rec.keys()
        if missing:
            sys.exit(f"lint --json line {lineno}: finding missing {sorted(missing)}")
    else:
        sys.exit(f"lint --json line {lineno}: unknown kind {rec.get('kind')!r}")
if summaries != 1:
    sys.exit(f"lint_check: expected exactly 1 summary line, got {summaries}")
print(f"lint_check: {len(lines)} JSONL line(s) validated")
PY
else
    # minimal fallback (no python3): the output must be exactly one
    # summary object declaring a clean tree
    last=$(printf '%s\n' "$json" | tail -n 1)
    for key in '"kind":"summary"' '"findings":0' '"stale_allows":0'; do
        if [[ "$last" != *"$key"* ]]; then
            echo "lint_check: summary line missing $key: $last" >&2
            exit 1
        fi
    done
    echo "lint_check: JSONL summary spot-checked (python3 unavailable)"
fi
